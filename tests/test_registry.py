"""Algorithm registry (repro.core.registry): the AlgorithmSpec API.

* registry contents + lookup errors name the registered set
* register_algorithm validation rejects inconsistent specs (unknown
  streams/planes, state flags without the machinery they promise)
* a custom spec registered at runtime — including full escape hatches
  (direction_fn + server_fn) — runs on every engine path with zero
  engine changes, and its state planes are allocated from its flags
* the new pure-spec algorithms (fedavgm / fedadagrad / fedyogi / fedacg)
  have the semantics their papers define (hand-checked single-round math
  + convergence), and fedavgm degenerates to fedavg at α = 1 exactly
* the kernels/README.md routing table is GENERATED from the registry —
  the test holds the file and `routing_table_md()` to byte agreement
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import (
    AlgorithmSpec,
    DirectionRow,
    FederatedEngine,
    FoldPass,
    describe_algorithm,
    get_algorithm,
    list_algorithms,
    register_algorithm,
    unregister_algorithm,
)
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

BUILTINS = ("fedavg", "fedcm", "fedadam", "scaffold", "feddyn", "mimelite",
            "fedavgm", "fedadagrad", "fedyogi", "fedacg")


# ----------------------------------------------------------------------
# registry mechanics
# ----------------------------------------------------------------------


def test_builtins_registered():
    names = list_algorithms()
    for n in BUILTINS:
        assert n in names, n
    assert names == tuple(sorted(names))


def test_get_algorithm_unknown_names_registry():
    with pytest.raises(KeyError, match="fedcm"):
        get_algorithm("sgd")


def test_duplicate_registration_rejected_unless_override():
    spec = get_algorithm("fedavg")
    with pytest.raises(ValueError, match="already registered"):
        register_algorithm(spec)
    assert register_algorithm(spec, override=True) is spec  # idempotent replace


@pytest.mark.parametrize("bad,match", [
    (dict(name="x", direction_row=DirectionRow(aux=(("nope", 1.0),))),
     "unknown direction stream"),
    (dict(name="x", direction_row=DirectionRow(aux=(("client_state", 1.0),))),
     "needs_client_state"),
    (dict(name="x", direction_row=DirectionRow(aux=(("momentum", 1.0),))),
     "needs_momentum_broadcast"),
    (dict(name="x", needs_client_state=True), "state_update_fn"),
    (dict(name="x", client_state_uplink=True), "without client state"),
    (dict(name="x", fold=(FoldPass("nope"),)), "unknown fold plane"),
    (dict(name="x", fold=(FoldPass("state_delta"),)), "without client state"),
    (dict(name="x", fold=(FoldPass("extra"),)), "without needs_full_grad"),
    (dict(name="x", fold=()), "escape hatch"),
    # a bare spec's default fold is the identity — the server would never
    # move; registration must refuse rather than silently freeze training
    (dict(name="x"), "never move"),
    (dict(name="x", fold=(FoldPass("delta", c_mm=1.0, c_md=0.0, c_xd=0.0),)),
     "never move"),
    (dict(name="x", direction_fn=lambda *a: a), "exactly one of"),
    (dict(name="x", momentum_store="bf16"), "momentum_store"),
])
def test_spec_validation(bad, match):
    with pytest.raises(ValueError, match=match):
        register_algorithm(AlgorithmSpec(**bad))


def test_state_plane_flags_drive_allocation():
    """FedState allocation is derived from the spec flags: stateless specs
    carry NO second-moment / client-state planes at all."""
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=200, n_test=8)
    model = mlp_classifier((8, 8, 4))
    for algo, wants_v, wants_cst in [("fedcm", False, False),
                                     ("fedadagrad", True, False),
                                     ("scaffold", False, True)]:
        cfg = FedConfig(algo=algo, num_clients=4, cohort_size=2, local_steps=1)
        eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
        st = eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
        assert (st.server.second_moment is not None) == wants_v, algo
        assert (st.client_states is not None) == wants_cst, algo


# ----------------------------------------------------------------------
# custom registration: new algorithms are data, the engine never changes
# ----------------------------------------------------------------------


def _toy_setup(algo, **kw):
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=800, n_test=8)
    model = mlp_classifier((8, 16, 4))
    base = dict(algo=algo, num_clients=10, cohort_size=3, local_steps=2,
                participation="fixed")
    base.update(kw)
    cfg = FedConfig(**base)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    return cfg, eng, data, model


def _fresh(eng, model):
    return eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))


def _close(a, b, atol=1e-5, rtol=1e-5):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=rtol, atol=atol)


def test_custom_affine_spec_runs_every_path():
    """A brand-new affine spec (declared as pure data) passes the same
    flat-vs-tree / kernel / async-bitwise contracts as the builtins."""
    register_algorithm(AlgorithmSpec(
        name="_test_damped",
        # damped SGD with a proximal pull toward the round anchor
        direction_row=DirectionRow(c_g=0.7, c_x=0.05),
        fold=(FoldPass("delta", c_mm=0.0,
                       c_md=lambda cfg, e, n: -1.0 / (e * cfg.local_steps),
                       c_xd=lambda cfg, e, n: cfg.eta_g),),
    ), override=True)
    try:
        cfg, eng, data, model = _toy_setup("_test_damped")
        eng_tree = FederatedEngine(replace(cfg, use_flat_plane=False),
                                   eng.loss_fn, batch_size=8)
        eng_k = FederatedEngine(replace(cfg, use_fused_kernel=True),
                                eng.loss_fn, batch_size=8)
        s_f, _ = eng.run_rounds(_fresh(eng, model), data, 3)
        s_t, _ = eng_tree.run_rounds(_fresh(eng_tree, model), data, 3)
        s_k, _ = eng_k.run_rounds(_fresh(eng_k, model), data, 3)
        s_a, _ = eng.run_rounds_async(_fresh(eng, model), data, 3,
                                      pipeline_depth=1, staleness=0)
        _close(s_f.params, s_t.params)
        _close(s_f.params, s_k.params)
        for a, b in zip(jax.tree_util.tree_leaves(s_f.params),
                        jax.tree_util.tree_leaves(s_a.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        unregister_algorithm("_test_damped")


def test_custom_escape_hatch_spec_runs_both_paths():
    """Full escape hatches (non-affine direction_fn + server_fn) still ride
    every engine path; under use_fused_kernel the server falls back to the
    jnp reduction of the (C, P) planes."""
    def sign_dir(cfg, m, cst, x, x0, g):
        return jax.tree_util.tree_map(jnp.sign, g)

    def avg_server(cfg, params, st, mean_delta, mean_sd, mean_extra, n_active, eta_l):
        new = jax.tree_util.tree_map(lambda p, d: p + cfg.eta_g * d,
                                     params, mean_delta)
        return new, st._replace(round=st.round + 1)

    register_algorithm(AlgorithmSpec(
        name="_test_signsgd", direction_row=None, direction_fn=sign_dir,
        fold=(), server_fn=avg_server,
    ), override=True)
    try:
        cfg, eng, data, model = _toy_setup("_test_signsgd")
        eng_tree = FederatedEngine(replace(cfg, use_flat_plane=False),
                                   eng.loss_fn, batch_size=8)
        eng_k = FederatedEngine(replace(cfg, use_fused_kernel=True),
                                eng.loss_fn, batch_size=8)
        s_f, m_f = eng.run_rounds(_fresh(eng, model), data, 3)
        s_t, _ = eng_tree.run_rounds(_fresh(eng_tree, model), data, 3)
        s_k, _ = eng_k.run_rounds(_fresh(eng_k, model), data, 3)
        _close(s_f.params, s_t.params)
        _close(s_f.params, s_k.params)
        assert np.all(np.isfinite(np.asarray(m_f.loss)))
    finally:
        unregister_algorithm("_test_signsgd")


# ----------------------------------------------------------------------
# new pure-spec algorithms: semantics
# ----------------------------------------------------------------------


def quad_loss(params, batch):
    c = batch["c"]  # (B, 2) — rows identical per client
    return 0.5 * jnp.mean(jnp.sum((params["x"][None] - c) ** 2, axis=-1))


def _quad_round(algo_name, params, centers, K=1, **cfg_kw):
    base = dict(algo=algo_name, num_clients=4, cohort_size=4, local_steps=K,
                alpha=0.5, eta_l=0.1, eta_g=1.0, weight_decay=0.0,
                eta_l_decay=1.0, participation="fixed")
    base.update(cfg_kw)
    cfg = FedConfig(**base)
    eng = FederatedEngine(cfg, quad_loss, batch_size=2)
    state = eng.init(params, jax.random.PRNGKey(0))
    C = centers.shape[0]
    batches = {"c": jnp.broadcast_to(centers[:, None, None, :], (C, K, 2, 2))}
    new, m = eng.round_step(state, batches, jnp.arange(4), jnp.ones(4, bool))
    return cfg, state, new, m


def test_fedavgm_server_heavy_ball_math():
    """Round 0 (m=0): FedAvgM's step equals FedAvg's; round 1 adds β·m."""
    params = {"x": jnp.array([1.0, -2.0])}
    centers = jnp.array([[0.0, 0.0], [2.0, 2.0], [1.0, 1.0], [-1.0, 3.0]])
    cfg, old, new, _ = _quad_round("fedavgm", params, centers, K=1, alpha=0.5)
    g = np.mean(np.asarray(params["x"])[None] - np.asarray(centers), axis=0)
    # m_1 = (1−α)·0 + pg = pg = g (K=1, plain-SGD clients); x − η_g·η_l·m
    np.testing.assert_allclose(np.asarray(new.params["x"]),
                               np.asarray(params["x"]) - cfg.eta_g * cfg.eta_l * g,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new.server.momentum["x"]), g, rtol=1e-5)


def test_fedavgm_alpha1_is_fedavg():
    """α = 1 kills the momentum carry-over: FedAvgM degenerates to FedAvg
    (to f32 reassociation — FedAvg steps x + η_g·mean in the fold row,
    FedAvgM steps x − η_g·η_l·K·m' in its post, algebraically equal)."""
    cfg, eng, data, model = _toy_setup("fedavgm", alpha=1.0)
    eng_avg = FederatedEngine(replace(cfg, algo="fedavg"), eng.loss_fn, batch_size=8)
    s_m, _ = eng.run_rounds(_fresh(eng, model), data, 4)
    s_a, _ = eng_avg.run_rounds(_fresh(eng_avg, model), data, 4)
    _close(s_m.params, s_a.params, atol=1e-6, rtol=1e-5)


def test_fedadagrad_accumulates_second_moment():
    """v is monotone non-decreasing (no decay) — the Adagrad signature —
    and the step uses the adaptive denominator."""
    params = {"x": jnp.array([10.0, 10.0])}
    centers = jnp.broadcast_to(jnp.zeros(2), (4, 2))
    cfg, old, new, _ = _quad_round("fedadagrad", params, centers, K=1, alpha=0.5)
    g = np.asarray(params["x"])  # ∇ = x − 0
    # m = α·g; v = 0 + g²; step = η_g·α·g/(|g| + τ)
    expect = cfg.eta_g * cfg.alpha * g / (np.abs(g) + cfg.adam_tau)
    np.testing.assert_allclose(
        np.asarray(old.params["x"]) - np.asarray(new.params["x"]), expect, rtol=1e-5
    )
    v1 = np.asarray(new.server.second_moment["x"])
    np.testing.assert_allclose(v1, g**2, rtol=1e-5)
    # second round: v only grows (snapshot before run_rounds donates st)
    _, eng, data, model = _toy_setup("fedadagrad")
    st, _ = eng.run_rounds(_fresh(eng, model), data, 1)
    v_prev = [np.array(l) for l in jax.tree_util.tree_leaves(st.server.second_moment)]
    st2, _ = eng.run_rounds(st, data, 1)
    for a, b in zip(v_prev, jax.tree_util.tree_leaves(st2.server.second_moment)):
        assert np.all(np.asarray(b) >= a - 1e-12)


def test_fedyogi_differs_from_fedadagrad_only_in_v():
    """Same fold row, different v rule: first round from v=0 they agree in
    m but diverge in v (yogi's sign-controlled update)."""
    params = {"x": jnp.array([3.0, -4.0])}
    centers = jnp.array([[0.0, 1.0], [1.0, 0.0], [-1.0, 0.0], [0.0, -1.0]])
    _, _, new_a, _ = _quad_round("fedadagrad", params, centers, K=1)
    _, _, new_y, _ = _quad_round("fedyogi", params, centers, K=1)
    np.testing.assert_allclose(np.asarray(new_a.server.momentum["x"]),
                               np.asarray(new_y.server.momentum["x"]), rtol=1e-6)
    assert not np.allclose(np.asarray(new_a.server.second_moment["x"]),
                           np.asarray(new_y.server.second_moment["x"]))


def test_fedacg_lookahead_step():
    """Round 0 (m=0): m_1 = pg, step = η_g·η_l·K·(pg + λ·m_1) — the
    Nesterov lookahead makes the first step (1+λ)× FedAvg's."""
    params = {"x": jnp.array([1.0, -2.0])}
    centers = jnp.array([[0.0, 0.0], [2.0, 2.0], [1.0, 1.0], [-1.0, 3.0]])
    cfg, old, new, _ = _quad_round("fedacg", params, centers, K=1, acg_lambda=0.5)
    g = np.mean(np.asarray(params["x"])[None] - np.asarray(centers), axis=0)
    expect = cfg.eta_g * cfg.eta_l * (1.0 + cfg.acg_lambda) * g
    np.testing.assert_allclose(
        np.asarray(old.params["x"]) - np.asarray(new.params["x"]), expect, rtol=1e-5
    )


@pytest.mark.parametrize("algo,kw,rounds", [
    ("fedavgm", dict(alpha=0.5), 40),
    # adagrad's denominator only accumulates — give it the paper's
    # absolute server lr and enough rounds for the 1/√T tail
    ("fedadagrad", dict(alpha=0.5, eta_g=1.0), 120),
    ("fedyogi", dict(alpha=0.5, eta_g=0.3), 40),
    ("fedacg", dict(acg_lambda=0.5), 40),
])
def test_new_algorithms_descend_on_convex(algo, kw, rounds):
    centers = jnp.array([[1.0, 0.0], [0.0, 1.0], [-1.0, 0.0], [0.0, -1.0]])
    target = np.mean(np.asarray(centers), axis=0)
    base = dict(algo=algo, num_clients=4, cohort_size=4, local_steps=4,
                eta_l=0.1, eta_g=1.0, weight_decay=0.0, eta_l_decay=1.0,
                participation="fixed")
    base.update(kw)
    cfg = FedConfig(**base)
    eng = FederatedEngine(cfg, quad_loss, batch_size=2)
    state = eng.init({"x": jnp.array([6.0, -6.0])}, jax.random.PRNGKey(0))
    batches = {"c": jnp.broadcast_to(centers[:, None, None, :], (4, 4, 2, 2))}
    ids, mask = jnp.arange(4), jnp.ones(4, bool)
    d0 = float(jnp.linalg.norm(state.params["x"] - jnp.asarray(target)))
    for _ in range(rounds):
        state, _ = eng.round_step(state, batches, ids, mask)
    d1 = float(jnp.linalg.norm(state.params["x"] - jnp.asarray(target)))
    assert d1 < 0.2 * d0, (algo, d0, d1)


def test_new_algorithms_payload_is_fedavg_shaped():
    """The family additions are all server-side: §4.2 accounting must
    charge them exactly FedAvg's wire footprint (derived from the flags)."""
    from repro.utils.trees import tree_bytes

    model = mlp_classifier((8, 16, 4))
    params = model.init(jax.random.PRNGKey(0))
    P = tree_bytes(params)
    for algo in ("fedavgm", "fedadagrad", "fedyogi", "fedacg"):
        cfg = FedConfig(algo=algo)
        eng = FederatedEngine(cfg, classification_loss(model.apply))
        pay = eng.payload_bytes(params)
        assert pay == {"down_per_client": P, "up_per_client": P}, algo


# ----------------------------------------------------------------------
# README routing table is generated from the registry
# ----------------------------------------------------------------------


def test_readme_routing_table_matches_registry():
    """kernels/README.md embeds `routing_table_md()` verbatim between the
    generation markers — regenerate with
    ``PYTHONPATH=src python -m repro.core.registry --write``."""
    from repro.core.registry import sync_readme

    assert sync_readme(write=False), (
        "kernels/README.md routing table is stale — run "
        "`PYTHONPATH=src python -m repro.core.registry --write`"
    )


def test_describe_algorithm_rows():
    d = describe_algorithm(get_algorithm("scaffold"))
    assert d["algorithm"] == "scaffold"
    assert "client_state" in d["local step"]
    assert "×2" in d["server fold"]
    assert "client_state" in d["state planes"]
    d = describe_algorithm(get_algorithm("fedadam"))
    assert "post" in d["server fold"]
    assert "second_moment" in d["state planes"]
