"""Per-algorithm unit tests: server updates + control-variate semantics.

Checked against hand-rolled single-round math on a 2-parameter quadratic —
these catch sign/scale errors the integration tests would blur out.
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import FederatedEngine, sample_cohort
from repro.core.algorithms import get_algorithm, server_init
from repro.utils.trees import tree_norm, tree_sub


def quad_loss(params, batch):
    """f(x) = 0.5‖x − c‖²; per-client c arrives via the batch."""
    c = batch["c"]  # (B, 2) — rows identical per client
    return 0.5 * jnp.mean(jnp.sum((params["x"][None] - c) ** 2, axis=-1))


def _cfg(algo, **kw):
    base = dict(algo=algo, num_clients=4, cohort_size=4, local_steps=2,
                alpha=0.5, eta_l=0.1, eta_g=1.0, weight_decay=0.0,
                eta_l_decay=1.0, participation="fixed")
    base.update(kw)
    return FedConfig(**base)


def _batches(centers, K):
    """centers: (C, 2) per cohort-client targets → (C, K, B=2, 2) batches."""
    C = centers.shape[0]
    c = jnp.broadcast_to(centers[:, None, None, :], (C, K, 2, 2))
    return {"c": c}


def _run_round(algo_name, params, centers, K=2, **cfg_kw):
    cfg = _cfg(algo_name, local_steps=K, **cfg_kw)
    eng = FederatedEngine(cfg, quad_loss, batch_size=2)
    state = eng.init(params, jax.random.PRNGKey(0))
    ids = jnp.arange(4)
    mask = jnp.ones(4, bool)
    new, m = eng.round_step(state, _batches(centers, K), ids, mask)
    return cfg, state, new, m


def test_fedavg_server_math():
    """FedAvg, K=1, full participation: x⁺ = x − η_g·η_l·mean∇f_i(x)."""
    params = {"x": jnp.array([1.0, -2.0])}
    centers = jnp.array([[0.0, 0.0], [2.0, 2.0], [1.0, 1.0], [-1.0, 3.0]])
    cfg, old, new, _ = _run_round("fedavg", params, centers, K=1)
    mean_grad = np.mean(np.asarray(params["x"])[None] - np.asarray(centers), axis=0)
    expect = np.asarray(params["x"]) - cfg.eta_g * cfg.eta_l * mean_grad
    np.testing.assert_allclose(np.asarray(new.params["x"]), expect, rtol=1e-6)


def test_fedcm_first_round_equals_fedavg():
    """Δ_0 = 0 ⇒ round 0 of FedCM scales client grads by α (v = α·g)."""
    params = {"x": jnp.array([1.0, -2.0])}
    centers = jnp.array([[0.0, 0.0], [2.0, 2.0], [1.0, 1.0], [-1.0, 3.0]])
    cfg, old, new, _ = _run_round("fedcm", params, centers, K=1, alpha=0.5)
    # with K=1: Δ_i = −η_l·α·g_i  ⇒  x⁺ = x − η_g·η_l·α·mean(g)
    mean_grad = np.mean(np.asarray(params["x"])[None] - np.asarray(centers), axis=0)
    expect = np.asarray(params["x"]) - cfg.eta_g * cfg.eta_l * cfg.alpha * mean_grad
    np.testing.assert_allclose(np.asarray(new.params["x"]), expect, rtol=1e-6)


def test_scaffold_control_variates_converge_on_heterogeneous_quadratics():
    """With c_i ≈ ∇f_i and c ≈ ∇f, SCAFFOLD's local steps follow the GLOBAL
    gradient: on heterogeneous quadratics it must converge to the mean center
    (which plain FedAvg with few clients ALSO does — so additionally check
    that the control variates become nonzero and the drift shrinks)."""
    params = {"x": jnp.array([5.0, 5.0])}
    centers = jnp.array([[0.0, 0.0], [2.0, 0.0], [0.0, 2.0], [-2.0, -2.0]])
    cfg = _cfg("scaffold", local_steps=4)
    eng = FederatedEngine(cfg, quad_loss, batch_size=2)
    state = eng.init(params, jax.random.PRNGKey(0))
    ids, mask = jnp.arange(4), jnp.ones(4, bool)
    for _ in range(60):
        state, _ = eng.round_step(state, _batches(centers, 4), ids, mask)
    target = np.mean(np.asarray(centers), axis=0)
    np.testing.assert_allclose(np.asarray(state.params["x"]), target, atol=1e-2)
    assert float(tree_norm(state.client_states)) > 0.0


def test_feddyn_fixed_point_is_stationary():
    """At x* = mean(c_i) with λ_i = ∇f_i(x*), FedDyn is stationary."""
    centers = jnp.array([[1.0, 0.0], [0.0, 1.0], [-1.0, 0.0], [0.0, -1.0]])
    target = jnp.mean(centers, axis=0)
    params = {"x": target}
    cfg = _cfg("feddyn", local_steps=8, feddyn_alpha=0.1, eta_l=0.05)
    eng = FederatedEngine(cfg, quad_loss, batch_size=2)
    state = eng.init(params, jax.random.PRNGKey(0))
    # hand-set λ_i = ∇f_i(x*) = x* − c_i: local objectives then share x* as
    # minimizer, so FedDyn must stay put
    state = state._replace(client_states={"x": jnp.stack([(target - c) for c in centers])})
    ids, mask = jnp.arange(4), jnp.ones(4, bool)
    for _ in range(30):
        state, _ = eng.round_step(state, _batches(centers, 8), ids, mask)
    # stationary: parameters stay near x*
    np.testing.assert_allclose(np.asarray(state.params["x"]), np.asarray(target), atol=5e-2)


def test_feddyn_converges_from_offset():
    centers = jnp.array([[1.0, 0.0], [0.0, 1.0], [-1.0, 0.0], [0.0, -1.0]])
    params = {"x": jnp.array([4.0, -3.0])}
    cfg = _cfg("feddyn", local_steps=10, feddyn_alpha=0.1, eta_l=0.05)
    eng = FederatedEngine(cfg, quad_loss, batch_size=2)
    state = eng.init(params, jax.random.PRNGKey(0))
    ids, mask = jnp.arange(4), jnp.ones(4, bool)
    for _ in range(80):
        state, _ = eng.round_step(state, _batches(centers, 10), ids, mask)
    target = np.mean(np.asarray(centers), axis=0)
    np.testing.assert_allclose(np.asarray(state.params["x"]), target, atol=5e-2)


def test_fedadam_uses_adaptive_denominator():
    """FedAdam's step is ≈ η_g·m/(√v+τ) — for a constant pseudo-gradient
    across rounds the step size approaches η_g·sign-like updates, unlike
    FedAvg whose step scales with the raw gradient magnitude."""
    params = {"x": jnp.array([10.0, 10.0])}
    centers = jnp.broadcast_to(jnp.zeros(2), (4, 2))  # all clients agree
    cfg, old, new, _ = _run_round("fedadam", params, centers, K=1, alpha=0.5)
    step = np.asarray(old.params["x"]) - np.asarray(new.params["x"])
    # v = β2·0 + (1−β2)·g²; m = α·g ⇒ step = η_g·α·g/(√((1−β2))·|g| + τ)
    g = np.asarray(params["x"])  # ∇ = x − 0
    expect = cfg.eta_g * cfg.alpha * g / (np.sqrt((1 - cfg.adam_beta2) * g**2) + cfg.adam_tau)
    np.testing.assert_allclose(step, expect, rtol=1e-5)


def test_mimelite_momentum_from_full_batch_grads():
    """MimeLite's m_{t+1} = (1−α)m + α·mean_i ∇f_i(x_t) (FULL batch)."""
    params = {"x": jnp.array([3.0, -1.0])}
    centers = jnp.array([[0.0, 0.0], [2.0, 2.0], [1.0, 1.0], [-1.0, 3.0]])
    cfg = _cfg("mimelite", alpha=0.25, local_steps=2)
    eng = FederatedEngine(cfg, quad_loss, batch_size=2)
    state = eng.init(params, jax.random.PRNGKey(0))
    ids, mask = jnp.arange(4), jnp.ones(4, bool)
    full = {"c": jnp.broadcast_to(centers[:, None, :], (4, 2, 2))}
    new, _ = eng.round_step(state, _batches(centers, 2), ids, mask, full_batches=full)
    mean_grad = np.mean(np.asarray(params["x"])[None] - np.asarray(centers), axis=0)
    expect_m = cfg.alpha * mean_grad  # m_0 = 0
    np.testing.assert_allclose(np.asarray(new.server.momentum["x"]), expect_m, rtol=1e-5)


def test_fedprox_k1_equals_fedavg():
    """K=1 from the anchor: x = x_t ⇒ the proximal term μ(x − x_t) is zero
    on the first local step, so a single-step FedProx round IS FedAvg."""
    params = {"x": jnp.array([1.0, -2.0])}
    centers = jnp.array([[0.0, 0.0], [2.0, 2.0], [1.0, 1.0], [-1.0, 3.0]])
    _, _, prox, _ = _run_round("fedprox", params, centers, K=1, fedprox_mu=0.3)
    _, _, avg, _ = _run_round("fedavg", params, centers, K=1)
    np.testing.assert_allclose(np.asarray(prox.params["x"]),
                               np.asarray(avg.params["x"]), rtol=1e-6)


def test_fedprox_two_step_hand_math():
    """K=2 hand-rolled: step 1 leaves x₁ = x₀ − η·g₁ (prox term zero);
    step 2 descends v = g₂ + μ(x₁ − x₀), so the proximal pull shows up as
    exactly −η·μ·(x₁ − x₀) relative to plain SGD.  On the quadratic
    f_i = ½‖x − c_i‖²: g = x − c_i."""
    x0 = np.array([1.0, -2.0])
    centers = np.array([[0.0, 0.0], [2.0, 2.0], [1.0, 1.0], [-1.0, 3.0]])
    mu, eta, eta_g = 0.3, 0.1, 1.0
    params = {"x": jnp.asarray(x0)}
    cfg, old, new, _ = _run_round("fedprox", params, jnp.asarray(centers),
                                  K=2, fedprox_mu=mu)
    deltas = []
    for c in centers:
        x1 = x0 - eta * (x0 - c)                       # prox term zero at x₀
        v2 = (x1 - c) + mu * (x1 - x0)                 # g₂ + μ·(x − x_t)
        x2 = x1 - eta * v2
        deltas.append(x2 - x0)
    expect = x0 + eta_g * np.mean(deltas, axis=0)      # x⁺ = x + η_g·mean(Δ)
    np.testing.assert_allclose(np.asarray(new.params["x"]), expect, rtol=1e-6)


def test_fedprox_mu_shrinks_client_drift():
    """Larger μ pulls the local iterates toward the anchor: the cohort-mean
    delta norm must shrink monotonically in μ on heterogeneous clients."""
    params = {"x": jnp.array([1.0, -2.0])}
    centers = jnp.array([[0.0, 0.0], [4.0, 4.0], [2.0, -2.0], [-3.0, 3.0]])
    norms = []
    for mu in (0.0, 0.5, 2.0):
        _, _, _, m = _run_round("fedprox", params, centers, K=8, fedprox_mu=mu)
        norms.append(float(m.delta_norm))
    assert norms[0] > norms[1] > norms[2], norms


def test_all_algorithms_descend_on_convex():
    params = {"x": jnp.array([6.0, -6.0])}
    centers = jnp.array([[1.0, 0.0], [0.0, 1.0], [-1.0, 0.0], [0.0, -1.0]])
    target = np.mean(np.asarray(centers), axis=0)
    for algo in ["fedavg", "fedcm", "fedadam", "scaffold", "feddyn", "mimelite"]:
        cfg = _cfg(algo, local_steps=4, alpha=0.5 if algo != "feddyn" else 0.5)
        eng = FederatedEngine(cfg, quad_loss, batch_size=2)
        state = eng.init(params, jax.random.PRNGKey(0))
        ids, mask = jnp.arange(4), jnp.ones(4, bool)
        full = {"c": jnp.broadcast_to(centers[:, None, :], (4, 2, 2))}
        d0 = float(jnp.linalg.norm(state.params["x"] - jnp.asarray(target)))
        for _ in range(40):
            state, _ = eng.round_step(state, _batches(centers, 4), ids, mask, full)
        d1 = float(jnp.linalg.norm(state.params["x"] - jnp.asarray(target)))
        assert d1 < 0.2 * d0, (algo, d0, d1)
