"""Round-engine semantics: cohort sampling, state staleness, schedules."""
from dataclasses import replace

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import (
    FederatedEngine,
    cohort_capacity,
    local_learning_rate,
    sample_cohort,
)
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier


def test_fixed_cohort_exact_size_no_repeats():
    cfg = FedConfig(num_clients=50, cohort_size=10, participation="fixed")
    for s in range(20):
        ids, mask = sample_cohort(jax.random.PRNGKey(s), cfg)
        assert ids.shape == (10,)
        assert bool(mask.all())
        assert len(np.unique(np.asarray(ids))) == 10


def test_bernoulli_cohort_statistics():
    """Active count over many rounds ≈ Binomial(N, S/N) mean ± tolerance."""
    cfg = FedConfig(num_clients=200, cohort_size=10, participation="bernoulli")
    cap = cohort_capacity(cfg)
    assert cap >= 10
    counts = []
    for s in range(300):
        ids, mask = sample_cohort(jax.random.PRNGKey(s), cfg)
        assert ids.shape == (cap,)
        counts.append(int(mask.sum()))
        assert len(np.unique(np.asarray(ids))) == cap  # ids w/o replacement
    mean = np.mean(counts)
    assert abs(mean - 10) < 1.0, mean  # E = N·p = 10
    assert np.std(counts) > 1.0  # genuinely random (σ ≈ 3.1)


def test_eta_l_decay_schedule():
    cfg = FedConfig(eta_l=0.1, eta_l_decay=0.998)
    for t in [0, 1, 50]:
        np.testing.assert_allclose(
            float(local_learning_rate(cfg, jnp.int32(t))), 0.1 * 0.998**t, rtol=1e-5
        )


def _fed_setup(algo, **kw):
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=800, n_test=8)
    model = mlp_classifier((8, 16, 4))
    base = dict(algo=algo, num_clients=10, cohort_size=3, local_steps=2,
                participation="fixed")
    base.update(kw)
    cfg = FedConfig(**base)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    state = eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
    return cfg, eng, data, state


def test_scaffold_state_staleness():
    """Non-participating clients' control variates must NOT move — this is
    the staleness mechanism the paper blames for SCAFFOLD's 2%-participation
    degradation."""
    cfg, eng, data, state = _fed_setup("scaffold")
    rng, kc, kb = jax.random.split(state.rng, 3)
    ids, mask = sample_cohort(kc, cfg)
    batches = data.sample_round_batches(kb, ids, cfg.local_steps, 8)
    new, _ = eng.round_step(state._replace(rng=rng), batches, ids, mask)
    active = set(np.asarray(ids).tolist())
    old_c = jax.tree_util.tree_leaves(state.client_states)[0]
    new_c = jax.tree_util.tree_leaves(new.client_states)[0]
    for cid in range(cfg.num_clients):
        moved = float(jnp.max(jnp.abs(new_c[cid] - old_c[cid]))) > 0
        assert moved == (cid in active), cid


def test_bernoulli_mask_excludes_inactive_from_aggregate():
    """An inactive cohort slot must contribute nothing: running the same
    round with the inactive client's batches replaced by garbage must give
    identical parameters."""
    cfg, eng, data, state = _fed_setup("fedcm", participation="bernoulli",
                                       num_clients=10, cohort_size=3)
    rng, kc, kb = jax.random.split(state.rng, 3)
    ids, mask = sample_cohort(kc, cfg)
    mask = mask.at[-1].set(False)  # force at least one inactive slot
    batches = data.sample_round_batches(kb, ids, cfg.local_steps, 8)
    out1, _ = eng.round_step(state._replace(rng=rng), batches, ids, mask)
    garbage = jax.tree_util.tree_map(
        lambda a: a.at[-1].set(jnp.asarray(3 if jnp.issubdtype(a.dtype, jnp.integer) else 1e3, a.dtype)),
        batches,
    )
    out2, _ = eng.round_step(state._replace(rng=rng), garbage, ids, mask)
    for a, b in zip(jax.tree_util.tree_leaves(out1.params),
                    jax.tree_util.tree_leaves(out2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weight_decay_enters_local_gradient():
    cfg, eng, data, state = _fed_setup("fedavg", weight_decay=0.0)
    cfg_wd = replace(cfg, weight_decay=0.5)
    eng_wd = FederatedEngine(cfg_wd, eng.loss_fn, batch_size=8)
    rng, kc, kb = jax.random.split(state.rng, 3)
    ids, mask = sample_cohort(kc, cfg)
    batches = data.sample_round_batches(kb, ids, cfg.local_steps, 8)
    o1, _ = eng.round_step(state._replace(rng=rng), batches, ids, mask)
    o2, _ = eng_wd.round_step(state._replace(rng=rng), batches, ids, mask)
    d = sum(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(o1.params),
                        jax.tree_util.tree_leaves(o2.params))
    )
    assert d > 1e-6


def test_round_metrics_fields():
    cfg, eng, data, state = _fed_setup("fedcm")
    state, m = eng.run_round(state, data)
    assert float(m.loss) > 0
    assert int(m.n_active) == 3
    assert float(m.eta_l) == pytest.approx(0.1, rel=1e-5)
    assert float(m.bytes_down) == 2 * float(m.bytes_up)  # fedcm asymmetry


def test_make_eval_fn_exact_and_device_resident():
    """The lax.map eval must (a) return the exact full-dataset accuracy for
    ragged n, and (b) trace the predict_fn a constant number of times — NOT
    once per batch per call like the old host loop."""
    from repro.core import make_eval_fn

    model = mlp_classifier((8, 16, 4))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(137, 8)), jnp.float32)  # 137 % 50 != 0
    y = jnp.asarray(rng.integers(0, 4, size=(137,)), jnp.int32)

    calls = {"n": 0}

    def counting_apply(p, xb):
        calls["n"] += 1  # python-level: only incremented while TRACING
        return model.apply(p, xb)

    evaluate = make_eval_fn(counting_apply, batch_size=50)
    acc = evaluate(params, x, y)
    ref = float(jnp.mean((jnp.argmax(model.apply(params, x), -1) == y)
                         .astype(jnp.float32)))
    assert acc == pytest.approx(ref, abs=1e-6)
    traces_after_first = calls["n"]
    for _ in range(3):
        assert evaluate(params, x, y) == pytest.approx(ref, abs=1e-6)
    assert calls["n"] == traces_after_first  # cached: zero retraces
    # padding rows carry zero weight: a batch-multiple n agrees with itself
    acc100 = evaluate(params, x[:100], y[:100])
    ref100 = float(jnp.mean((jnp.argmax(model.apply(params, x[:100]), -1) == y[:100])
                            .astype(jnp.float32)))
    assert acc100 == pytest.approx(ref100, abs=1e-6)
