"""fed_train CLI → FedConfig wiring (the PR-2 ``use_flat_plane`` gap).

The driver builds its FedConfig from argv in ``resolve_config``; a flag
that parses but never reaches the config silently trains with the default
(exactly what happened to ``--flat-plane``'s predecessor).  ``--dryrun``
persists the RESOLVED config to an artifact, so the wiring is asserted
end-to-end: argv in → artifact out, no training."""
import json

import pytest

from repro.configs.base import FedConfig
from repro.launch.fed_train import (
    DRYRUN_ARTIFACT,
    build_parser,
    main,
    resolve_config,
)


def _resolved(argv):
    return resolve_config(build_parser().parse_args(argv))


def test_flat_plane_flag_wires_through():
    assert _resolved([]).use_flat_plane is FedConfig.use_flat_plane
    assert _resolved(["--flat-plane"]).use_flat_plane is True
    assert _resolved(["--no-flat-plane"]).use_flat_plane is False


def test_async_flags_wire_through():
    cfg = _resolved(["--pipeline-depth", "4", "--staleness", "2",
                     "--staleness-discount", "0.9"])
    assert cfg.pipeline_depth == 4
    assert cfg.staleness == 2
    assert cfg.staleness_discount == pytest.approx(0.9)
    assert _resolved([]).pipeline_depth == 1 and _resolved([]).staleness == 0


def test_fused_kernel_flag_wires_through():
    assert _resolved([]).use_fused_kernel is False
    assert _resolved(["--fused-kernel"]).use_fused_kernel is True


def test_cohort_shard_flag_wires_through():
    assert _resolved([]).cohort_shard == 0
    cfg = _resolved(["--cohort-shard", "4", "--fused-kernel"])
    assert cfg.cohort_shard == 4 and cfg.use_fused_kernel is True


def test_cohort_shard_requires_kernel_and_flat_plane():
    with pytest.raises(SystemExit):  # argparse error: needs --fused-kernel
        main(["--dryrun", "--cohort-shard", "2"])
    with pytest.raises(SystemExit):  # and the flat plane
        main(["--dryrun", "--cohort-shard", "2", "--fused-kernel",
              "--no-flat-plane"])


def test_cohort_shard_dryrun_records_mesh(tmp_path, monkeypatch):
    art = tmp_path / "fed_train_dryrun.json"
    monkeypatch.setattr("repro.launch.fed_train.DRYRUN_ARTIFACT", art)
    rc = main(["--dryrun", "--cohort-shard", "2", "--fused-kernel"])
    assert rc == 0
    got = json.loads(art.read_text())
    assert got["resolved_config"]["cohort_shard"] == 2
    assert got["cohort_mesh"] == {
        "axes": ["clients"], "shape": [2],
        "devices_visible": got["cohort_mesh"]["devices_visible"],
    }
    # no --cohort-shard → no mesh recorded
    rc = main(["--dryrun"])
    assert json.loads(art.read_text())["cohort_mesh"] is None


def test_dryrun_artifact_records_resolved_config(tmp_path, monkeypatch):
    art = tmp_path / "fed_train_dryrun.json"
    monkeypatch.setattr("repro.launch.fed_train.DRYRUN_ARTIFACT", art)
    rc = main(["--dryrun", "--no-flat-plane", "--fused-kernel",
               "--pipeline-depth", "2", "--staleness", "1",
               "--algo", "scaffold", "--clients", "7"])
    assert rc == 0
    got = json.loads(art.read_text())["resolved_config"]
    assert got["use_flat_plane"] is False
    assert got["use_fused_kernel"] is True
    assert got["pipeline_depth"] == 2
    assert got["staleness"] == 1
    assert got["algo"] == "scaffold"
    assert got["num_clients"] == 7
    assert json.loads(art.read_text())["engine_mode"] == "async_pipeline"


def test_per_round_conflicts_with_async():
    """--per-round (one jit dispatch per round) and the async pipelined
    engine (one fused program) are mutually exclusive — combining them
    must error instead of silently dropping --per-round."""
    for argv in (["--per-round", "--pipeline-depth", "2"],
                 ["--per-round", "--staleness", "1"],
                 ["--per-round", "--async"]):
        with pytest.raises(SystemExit) as e:
            main(argv + ["--dryrun"])
        assert e.value.code == 2  # argparse error exit


def test_algo_choices_come_from_registry(capsys):
    """--algo choices ARE the registry: a freshly registered name parses,
    an unknown one errors naming the registered set."""
    from repro.core import list_algorithms

    assert tuple(
        build_parser()._option_string_actions["--algo"].choices
    ) == list_algorithms()
    assert _resolved(["--algo", "fedavgm"]).algo == "fedavgm"
    with pytest.raises(SystemExit) as e:
        build_parser().parse_args(["--algo", "nope"])
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "fedcm" in err and "fedavgm" in err  # the registry list, rendered


def test_list_algos_prints_registry(capsys):
    """--list-algos prints every registered spec's state planes + kernel
    routing and exits 0 without touching data or the engine."""
    from repro.core import list_algorithms

    assert main(["--list-algos"]) == 0
    out = capsys.readouterr().out
    for name in list_algorithms():
        assert name in out
    assert "fed_direction" in out and "server_update" in out
    assert "client_state" in out  # state-plane requirements rendered


def test_fault_flags_wire_through():
    """Fault knobs land on cfg.fault as a FaultConfig; all-defaults keeps
    fault=None (the bitwise-preserved engine)."""
    assert _resolved([]).fault is None
    cfg = _resolved(["--fault-drop-rate", "0.2", "--fault-corrupt-rate",
                     "0.05", "--fault-corrupt-mode", "inf",
                     "--fault-deadline", "2.0",
                     "--fault-store-failure-rate", "0.1",
                     "--fault-seed", "7"])
    assert cfg.fault is not None
    assert cfg.fault.drop_rate == pytest.approx(0.2)
    assert cfg.fault.corrupt_rate == pytest.approx(0.05)
    assert cfg.fault.corrupt_mode == "inf"
    assert cfg.fault.deadline == pytest.approx(2.0)
    assert cfg.fault.store_failure_rate == pytest.approx(0.1)
    assert cfg.fault.seed == 7
    # any single nonzero knob materializes the config
    assert _resolved(["--quarantine-norm-mult", "5.0"]).fault is not None


def test_quorum_and_empty_cohort_flags_wire_through():
    assert _resolved([]).min_quorum == 0
    assert _resolved([]).allow_empty_cohort is False
    cfg = _resolved(["--min-quorum", "3", "--allow-empty-cohort"])
    assert cfg.min_quorum == 3 and cfg.allow_empty_cohort is True


def test_fault_flags_reach_dryrun_artifact(tmp_path, monkeypatch):
    art = tmp_path / "fed_train_dryrun.json"
    monkeypatch.setattr("repro.launch.fed_train.DRYRUN_ARTIFACT", art)
    rc = main(["--dryrun", "--fault-drop-rate", "0.3",
               "--fault-corrupt-rate", "0.02", "--min-quorum", "2",
               "--ckpt-every", "10", "--ckpt-dir", str(tmp_path)])
    assert rc == 0
    got = json.loads(art.read_text())
    rc_cfg = got["resolved_config"]
    assert rc_cfg["fault"]["drop_rate"] == pytest.approx(0.3)
    assert rc_cfg["fault"]["corrupt_rate"] == pytest.approx(0.02)
    assert rc_cfg["min_quorum"] == 2
    assert got["ckpt_every"] == 10
    # no fault flags → fault stays null in the artifact
    assert main(["--dryrun"]) == 0
    assert json.loads(art.read_text())["resolved_config"]["fault"] is None


def test_ckpt_flag_validations():
    """Snapshot flags constrain each other: ckpt needs a dir and the fused
    chunk loop; die-after/resume need ckpt-every."""
    for argv in (["--ckpt-every", "5"],                      # no --ckpt-dir
                 ["--ckpt-every", "5", "--ckpt-dir", "/tmp/x", "--async"],
                 ["--ckpt-every", "5", "--ckpt-dir", "/tmp/x", "--per-round"],
                 ["--die-after", "5", "--ckpt-dir", "/tmp/x"],  # no ckpt-every
                 ["--resume", "--ckpt-dir", "/tmp/x"]):
        with pytest.raises(SystemExit) as e:
            main(argv + ["--dryrun"])
        assert e.value.code == 2


def test_dryrun_artifact_default_mode(tmp_path, monkeypatch):
    art = tmp_path / "fed_train_dryrun.json"
    monkeypatch.setattr("repro.launch.fed_train.DRYRUN_ARTIFACT", art)
    assert main(["--dryrun"]) == 0
    got = json.loads(art.read_text())
    assert got["resolved_config"]["use_flat_plane"] is True
    assert got["engine_mode"] == "fused_scan"
    assert main(["--dryrun", "--per-round"]) == 0
    assert json.loads(art.read_text())["engine_mode"] == "per_round"
    assert main(["--dryrun", "--async"]) == 0
    assert json.loads(art.read_text())["engine_mode"] == "async_pipeline"


def test_serve_flags_reach_dryrun_artifact(tmp_path, monkeypatch):
    art = tmp_path / "fed_train_dryrun.json"
    monkeypatch.setattr("repro.launch.fed_train.DRYRUN_ARTIFACT", art)
    rc = main(["--dryrun", "--serve", "--ckpt-every", "2",
               "--ckpt-dir", str(tmp_path), "--round-deadline", "45",
               "--publish-retain", "3"])
    assert rc == 0
    sv = json.loads(art.read_text())["serve"]
    assert sv["enabled"] is True
    assert sv["round_deadline_s"] == pytest.approx(45.0)
    assert sv["publish_retain"] == 3
    assert sv["publish_every"] == 2
    # telemetry path defaults into the ckpt dir
    assert sv["telemetry_path"] == str(tmp_path / "telemetry.jsonl")
    # without --serve the knobs are recorded but disabled
    assert main(["--dryrun"]) == 0
    sv = json.loads(art.read_text())["serve"]
    assert sv["enabled"] is False and sv["publish_every"] is None


def test_dryrun_telemetry_schema_agrees_with_fleet(tmp_path, monkeypatch):
    """The artifact's telemetry block IS the fleet schema — a rename in
    either place makes --dryrun and the written rows disagree loudly."""
    from repro.fleet.telemetry import (
        FAULT_COUNTERS, ROUND_FIELDS, TELEMETRY_SCHEMA,
    )
    from repro.core.engine import RoundMetrics

    art = tmp_path / "fed_train_dryrun.json"
    monkeypatch.setattr("repro.launch.fed_train.DRYRUN_ARTIFACT", art)
    assert main(["--dryrun"]) == 0
    tel = json.loads(art.read_text())["telemetry"]
    assert tel["schema"] == TELEMETRY_SCHEMA
    assert tel["round_fields"] == list(ROUND_FIELDS)
    assert tel["fault_counters"] == list(FAULT_COUNTERS)
    assert set(tel["fault_counters"]) <= set(RoundMetrics._fields)


def test_serve_flag_validations_cli():
    """--serve requires the snapshot cadence (its publish source) and a
    checkpoint dir; retention ring must keep >= 2 versions."""
    for argv in (["--serve"],                                   # no ckpt
                 ["--serve", "--ckpt-every", "2"],              # no dir
                 ["--serve", "--ckpt-every", "2", "--ckpt-dir", "/tmp/x",
                  "--publish-retain", "1"]):
        with pytest.raises(SystemExit) as e:
            main(argv + ["--dryrun"])
        assert e.value.code == 2


def test_dryrun_artifact_static_contracts(tmp_path, monkeypatch):
    art = tmp_path / "fed_train_dryrun.json"
    monkeypatch.setattr("repro.launch.fed_train.DRYRUN_ARTIFACT", art)
    assert main(["--dryrun"]) == 0
    sc = json.loads(art.read_text())["static_contracts"]
    assert sc["donation_ok"] is True
    assert sc["transfer_guard_ok"] is True
    assert sc["trace_count"] == sc["trace_budget"] == 1
    assert "sync" in sc["path"]
    assert main(["--dryrun", "--async"]) == 0
    sc = json.loads(art.read_text())["static_contracts"]
    assert sc["donation_ok"] is True
    assert "async" in sc["path"]
