"""Cross-path model consistency: decode == forward, blocked == direct
attention, capacity-MoE ≈ dense-MoE, prefill cache == decode-built cache."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.models import build_model
from repro.models.layers import attend_blocked, attend_direct, moe_dropping, moe_ref

RNG = np.random.default_rng(0)

DECODE_ARCHS = ["llama3.2-1b", "mamba2-1.3b", "zamba2-7b", "gemma3-12b",
                "dbrx-132b", "starcoder2-7b", "qwen3-14b", "chameleon-34b",
                "llama4-maverick-400b-a17b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the teacher-forced forward pass.

    MoE archs: capacity binds only under training token counts — raise the
    capacity factor so routing is drop-free and the paths are comparable
    (decode routes per-token and never drops)."""
    from dataclasses import replace

    cfg = reduced(get_config(arch))
    if cfg.n_experts:
        cfg = replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0, cfg.vocab_size)
    logits_full, _, _ = model.apply(params, toks)
    cache = model.init_cache(params, 2, T)
    outs = []
    for t in range(T):
        lg, cache = model.decode_step(params, toks[:, t : t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(logits_full, np.float32),
        rtol=5e-3, atol=5e-3,
    )


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b", "gemma3-12b", "zamba2-7b"])
def test_prefill_cache_matches_decode_built_cache(arch):
    """Prefill's emitted cache lets decode continue exactly as if the prompt
    had been decoded token-by-token."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    P, G = 7, 3
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, P + G), 0, cfg.vocab_size)

    # path A: decode everything token by token
    cache_a = model.init_cache(params, 2, P + G)
    la = None
    for t in range(P + G):
        la, cache_a = model.decode_step(params, toks[:, t : t + 1], cache_a, jnp.int32(t))

    # path B: prefill P tokens, splice cache into a big buffer, decode G more
    _, pre_cache, _ = model.apply(params, toks[:, :P], return_cache=True)
    cache_b = model.init_cache(params, 2, P + G)

    def merge(dst, src):
        if (dst.ndim == src.ndim and dst.ndim >= 3 and dst.shape[:2] == src.shape[:2]
                and dst.shape[2] >= src.shape[2] and dst.shape[3:] == src.shape[3:]):
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), (0,) * dst.ndim)
        return src.astype(dst.dtype)

    cache_b = jax.tree_util.tree_map(merge, cache_b, pre_cache)
    lb = None
    for t in range(P, P + G):
        lb, cache_b = model.decode_step(params, toks[:, t : t + 1], cache_b, jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(la, np.float32), np.asarray(lb, np.float32), rtol=5e-3, atol=5e-3
    )


@pytest.mark.parametrize("window", [None, 13])
@pytest.mark.parametrize("q_block,kv_block", [(16, 16), (32, 16), (16, 32)])
def test_blocked_attention_matches_direct(window, q_block, kv_block):
    B, S, H, Hkv, hd = 2, 50, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    pos = jnp.arange(S)
    msk = pos[:, None] >= pos[None, :]
    if window is not None:
        msk &= pos[:, None] - pos[None, :] < window
    ref = attend_direct(q, k, v, msk[None, None], hd**-0.5)
    out = attend_blocked(
        q, k, v, causal=True, window=window, scale=hd**-0.5,
        q_positions=pos, kv_positions=pos, q_block=q_block, kv_block=kv_block,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_moe_dropping_matches_ref_at_high_capacity():
    """With capacity_factor high enough that nothing drops, the scatter/
    gather MoE must equal the dense masked reference exactly."""
    from dataclasses import replace

    cfg = reduced(get_config("dbrx-132b"))
    cfg = replace(cfg, capacity_factor=8.0)  # no drops
    from repro.models.layers import init_moe

    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 12, cfg.d_model)), jnp.float32)
    out_d, aux_d = moe_dropping(p, x, cfg=cfg)
    out_r, aux_r = moe_ref(p, x, cfg=cfg)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_d), float(aux_r), rtol=1e-5)


def test_moe_capacity_drops_tokens_but_stays_finite():
    from dataclasses import replace

    cfg = reduced(get_config("dbrx-132b"))
    cfg = replace(cfg, capacity_factor=0.25)  # aggressive dropping
    from repro.models.layers import init_moe

    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 64, cfg.d_model)), jnp.float32)
    out, aux = moe_dropping(p, x, cfg=cfg)
    assert np.all(np.isfinite(np.asarray(out)))
    # dropped tokens ⇒ output differs from the no-drop reference
    out_r, _ = moe_ref(p, x, cfg=cfg)
    assert float(jnp.max(jnp.abs(out - out_r))) > 1e-6


def test_gemma_local_global_period():
    cfg = get_config("gemma3-12b")
    from repro.models.transformer import period_layout

    slots, n_periods, tail = period_layout(cfg)
    assert len(slots) == 6 and n_periods == 8 and not tail
    assert [s.is_global for s in slots] == [False] * 5 + [True]


def test_zamba_shared_attention_is_shared():
    """All attention applications in the hybrid stack read ONE param set."""
    cfg = reduced(get_config("zamba2-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert "shared" in params
    from repro.models.transformer import period_layout

    slots, n_periods, tail = period_layout(get_config("zamba2-7b"))
    n_attn = sum(1 for s in slots if s.shared)
    assert n_attn == 1 and slots[-1].shared
    # 81 layers, attn_every=6 → 13 periods of 6 + 3 tail mamba layers
    assert n_periods == 13 and len(tail) == 3


def test_llama4_moe_interleave():
    cfg = get_config("llama4-maverick-400b-a17b")
    from repro.models.transformer import period_layout

    slots, n_periods, _ = period_layout(cfg)
    assert len(slots) == 2
    assert [s.is_moe for s in slots] == [False, True]
    assert cfg.shared_expert


def test_vlm_image_token_mask_path():
    """Chameleon consumes early-fused discrete tokens; image tokens are just
    vocab ids — verify a mixed batch runs and positions are respected."""
    cfg = reduced(get_config("chameleon-34b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    logits, _, _ = model.apply(params, toks)
    assert logits.shape == (2, 16, cfg.vocab_size)
