"""Out-of-core population engine tests (store + sampler + streaming data).

The contract under test (ISSUE: million-client population engine):

* **Store-backed = resident, f32-bitwise.**  ``population_store="host"``
  runs the SAME jitted round functions as the resident engine,
  parameterized by host-gathered ``(C, P)`` rows — so at matched cohorts
  the trajectories agree bitwise with the per-round resident oracle
  (``run_round`` × n) on the sync engine (jnp AND kernel paths) and with
  ``run_rounds_async`` on the kernel path (Pallas pins the op order).
  The async jnp path is held to tight f32 tolerance instead: the resident
  async engine is ONE scanned program and XLA's fusion choices across the
  scan boundary reassociate its jnp reductions at the ulp level — the
  same reason the repo holds ``run_rounds`` vs sequential ``run_round``
  to tolerance rather than bitwise.
* **No (N, ·) device plane** ever exists on the host path; host memory
  scales with TOUCHED clients.
* **Uniform availability is the legacy sampler, verbatim** (same key
  splits, same ``jax.random.choice``/scalar-p bernoulli) — pre-existing
  trajectories can't move.
* **Capacity clips are counted, not silent** (``RoundMetrics.n_clipped``).
* Checkpoint round-trip of a store-backed run via the template-free
  ``repro.checkpoint.ckpt.load_flat``.
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import load_checkpoint, load_flat, save_checkpoint
from repro.configs.base import FedConfig
from repro.core import FederatedEngine, cohort_capacity, sample_cohort, sample_cohort_ex
from repro.core.flat import FlatSpec
from repro.data import FederatedData, make_synthetic_classification
from repro.data.population import (
    HostPopulationStore,
    StreamingClientData,
    availability_log_weights,
)
from repro.models.small import classification_loss, mlp_classifier
from repro.sharding.rules import fed_state_specs


def _setup(algo, **kw):
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=800, n_test=8)
    model = mlp_classifier((8, 16, 4))
    base = dict(algo=algo, num_clients=10, cohort_size=3, local_steps=2,
                participation="fixed")
    base.update(kw)
    cfg = FedConfig(**base)
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    return cfg, classification_loss(model.apply), data, model


def _fresh(eng, model):
    return eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))


def _assert_bitwise(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _store_rows_vs_resident(eng_host, resident_state, spec):
    """Dense (N, P) view of the host store vs the resident stacked plane."""
    rows_ref = np.asarray(spec.ravel(resident_state.client_states, batch_dims=1))
    tree = eng_host.population.to_pytree()
    dense = np.zeros_like(rows_ref)
    dense[np.asarray(tree["ids"])] = np.asarray(tree["rows"])
    np.testing.assert_array_equal(dense, rows_ref)


# ----------------------------------------------------------------------
# store-backed engine vs resident oracle
# ----------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["scaffold", "feddyn"])
@pytest.mark.parametrize("kernel", [False, True])
def test_store_sync_bitwise_vs_resident(algo, kernel):
    cfg, loss_fn, data, model = _setup(algo, use_fused_kernel=kernel)
    eng_r = FederatedEngine(cfg, loss_fn, batch_size=8)
    sr = _fresh(eng_r, model)
    losses = []
    for _ in range(5):  # the per-round resident oracle
        sr, m = eng_r.run_round(sr, data)
        losses.append(np.asarray(m.loss))

    eng_h = FederatedEngine(replace(cfg, population_store="host"), loss_fn,
                            batch_size=8)
    sh, mh = eng_h.run_rounds(_fresh(eng_h, model), data, 5)

    assert sh.client_states is None  # no (N, P) device plane, ever
    _assert_bitwise((sr.params, sr.server.momentum),
                    (sh.params, sh.server.momentum))
    np.testing.assert_array_equal(np.stack(losses), np.asarray(mh.loss))
    _store_rows_vs_resident(eng_h, sr, FlatSpec.from_tree(sr.params))


@pytest.mark.parametrize("algo", ["scaffold", "feddyn"])
def test_store_async_kernel_bitwise_vs_resident(algo):
    cfg, loss_fn, data, model = _setup(
        algo, use_fused_kernel=True, pipeline_depth=2, staleness=1)
    eng_r = FederatedEngine(cfg, loss_fn, batch_size=8)
    sr, mr = eng_r.run_rounds_async(_fresh(eng_r, model), data, 6)

    eng_h = FederatedEngine(replace(cfg, population_store="host"), loss_fn,
                            batch_size=8)
    sh, mh = eng_h.run_rounds_async(_fresh(eng_h, model), data, 6)

    assert sh.client_states is None
    _assert_bitwise((sr.params, sr.server.momentum),
                    (sh.params, sh.server.momentum))
    np.testing.assert_array_equal(np.asarray(mr.loss), np.asarray(mh.loss))
    np.testing.assert_array_equal(np.asarray(mr.folded), np.asarray(mh.folded))
    _store_rows_vs_resident(eng_h, sr, FlatSpec.from_tree(sr.params))


@pytest.mark.parametrize("algo", ["scaffold", "feddyn"])
def test_store_async_jnp_matches_resident_tight(algo):
    # jnp path: same host-loop schedule (the kernel test above pins it
    # bitwise), but XLA refuses to reassociate identically across the
    # resident scan boundary — hold the trajectory to f32-noise tolerance
    cfg, loss_fn, data, model = _setup(algo, pipeline_depth=2, staleness=1)
    eng_r = FederatedEngine(cfg, loss_fn, batch_size=8)
    sr, mr = eng_r.run_rounds_async(_fresh(eng_r, model), data, 6)

    eng_h = FederatedEngine(replace(cfg, population_store="host"), loss_fn,
                            batch_size=8)
    sh, mh = eng_h.run_rounds_async(_fresh(eng_h, model), data, 6)

    for la, lb in zip(
        jax.tree_util.tree_leaves((sr.params, sr.server.momentum)),
        jax.tree_util.tree_leaves((sh.params, sh.server.momentum)),
    ):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(mr.loss), np.asarray(mh.loss),
                               rtol=2e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(mr.folded), np.asarray(mh.folded))


def test_store_sharding_specs_drop_client_plane():
    cfg, *_ = _setup("scaffold")
    cfg_h = replace(cfg, population_store="host")
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    p_specs = jax.sharding.PartitionSpec()
    assert fed_state_specs(p_specs, cfg, mesh)["client_states"] is not None
    assert fed_state_specs(p_specs, cfg_h, mesh)["client_states"] is None


# ----------------------------------------------------------------------
# checkpoint round-trip (template-free store restore)
# ----------------------------------------------------------------------


def test_store_checkpoint_roundtrip(tmp_path):
    cfg, loss_fn, data, model = _setup("scaffold", population_store="host")
    eng1 = FederatedEngine(cfg, loss_fn, batch_size=8)
    st = _fresh(eng1, model)
    st, _ = eng1.run_rounds(st, data, 3)
    touched_at_save = eng1.population.touched
    ckpt_tree = {"state": st, "store": eng1.population.to_pytree()}
    save_checkpoint(str(tmp_path), 3, ckpt_tree,
                    meta={"touched": touched_at_save})
    st_cont, _ = eng1.run_rounds(st, data, 2)  # the uninterrupted reference

    # cold restore into a fresh engine: params/server/rng via the template
    # path, the run-dependent (M, P) store packing via template-free
    # load_flat (no template can predict M = touched clients)
    eng2 = FederatedEngine(cfg, loss_fn, batch_size=8)
    template = {"state": _fresh(eng2, model),
                "store": {"ids": np.zeros(0, np.int32),
                          "rows": np.zeros((0, 0), np.float32)}}
    flat, meta = load_flat(str(tmp_path))
    assert meta["step"] == 3 and meta["touched"] == touched_at_save
    restored, _ = load_checkpoint(
        str(tmp_path), 3,
        {"state": template["state"],
         "store": {"ids": flat["store/ids"], "rows": flat["store/rows"]}},
    )
    eng2.population = HostPopulationStore.from_pytree(
        restored["store"], cfg.num_clients,
        plane_size=eng1.population.plane_size,
    )
    st2, _ = eng2.run_rounds(restored["state"], data, 2)

    _assert_bitwise((st_cont.params, st_cont.server.momentum, st_cont.rng),
                    (st2.params, st2.server.momentum, st2.rng))
    t1, t2 = eng1.population.to_pytree(), eng2.population.to_pytree()
    _assert_bitwise(t1, t2)


# ----------------------------------------------------------------------
# sampler: clips, legacy-bitwise uniform, availability processes
# ----------------------------------------------------------------------


def test_bernoulli_clip_is_counted_at_small_n():
    # N=40, S=30 at capacity sigma 0 → cap = 30, p = 0.75: the binomial
    # draw exceeds its mean ~42% of rounds.  The pre-store engine silently
    # truncated those rounds (participation bias toward low draws); the
    # sampler now surfaces every overflow in n_clipped.
    cfg = FedConfig(algo="fedcm", num_clients=40, cohort_size=30,
                    participation="bernoulli", bernoulli_capacity_sigma=0.0)
    cap = cohort_capacity(cfg)
    assert cap == 30
    clipped_rounds, key = 0, jax.random.PRNGKey(0)
    for _ in range(200):
        key, k = jax.random.split(key)
        ids, mask, n_clipped = sample_cohort_ex(k, cfg)
        assert ids.shape == (cap,) and mask.shape == (cap,)
        n_clipped = int(n_clipped)
        assert n_clipped >= 0
        if n_clipped > 0:
            clipped_rounds += 1
            assert int(mask.sum()) == cap  # clipped ⇒ mask saturated
    assert 0.25 < clipped_rounds / 200 < 0.65

    ids2, mask2 = sample_cohort(jax.random.PRNGKey(1), cfg)  # 2-tuple wrapper
    assert ids2.shape == (cap,) and mask2.shape == (cap,)


@pytest.mark.parametrize("participation", ["fixed", "bernoulli"])
def test_uniform_availability_is_the_legacy_draw(participation):
    # the exact legacy two-key sampler, reproduced by hand: any drift here
    # moves every pre-existing trajectory in the repo
    cfg = FedConfig(algo="fedcm", num_clients=50, cohort_size=10,
                    participation=participation)
    assert availability_log_weights(cfg) is None
    cap = cohort_capacity(cfg)
    key = jax.random.PRNGKey(7)
    ids, mask, _ = sample_cohort_ex(key, cfg)

    k_perm, k_n = jax.random.split(key)
    ref_ids = jax.random.choice(k_perm, cfg.num_clients, (cap,), replace=False)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref_ids))
    if participation == "fixed":
        assert bool(mask.all())
    else:
        p = cfg.cohort_size / cfg.num_clients
        s = jnp.clip(jnp.sum(jax.random.bernoulli(
            k_n, p, (cfg.num_clients,))).astype(jnp.int32), 1, cap)
        np.testing.assert_array_equal(np.asarray(mask),
                                      np.asarray(jnp.arange(cap) < s))


def test_zipf_availability_biases_low_ids():
    n = 1000
    cfg_u = FedConfig(algo="fedcm", num_clients=n, cohort_size=50,
                      participation="fixed")
    cfg_z = replace(cfg_u, availability="zipf", zipf_exponent=1.5)
    key = jax.random.PRNGKey(0)
    mean_u, mean_z = [], []
    for i in range(20):
        k = jax.random.fold_in(key, i)
        mean_u.append(float(np.mean(np.asarray(sample_cohort_ex(k, cfg_u)[0]))))
        mean_z.append(float(np.mean(np.asarray(sample_cohort_ex(k, cfg_z)[0]))))
    # zipf head (low ids) dominates; uniform sits near N/2
    assert np.mean(mean_z) < 0.5 * np.mean(mean_u)


def test_diurnal_availability_is_time_dependent():
    cfg = FedConfig(algo="fedcm", num_clients=200, cohort_size=20,
                    participation="fixed", availability="diurnal",
                    diurnal_period=10.0, diurnal_amplitude=0.95)
    key = jax.random.PRNGKey(3)
    ids_t0 = np.sort(np.asarray(sample_cohort_ex(key, cfg, t=0)[0]))
    ids_t5 = np.sort(np.asarray(sample_cohort_ex(key, cfg, t=5)[0]))
    # half a period later the sinusoid has rotated phase by π — the same
    # key must select a (mostly) different cohort
    assert not np.array_equal(ids_t0, ids_t5)
    w0 = availability_log_weights(cfg, t=0)
    w5 = availability_log_weights(cfg, t=5)
    assert not np.allclose(np.asarray(w0), np.asarray(w5))


def test_dropout_thins_but_never_empties():
    cfg = FedConfig(algo="fedcm", num_clients=100, cohort_size=16,
                    participation="fixed", dropout_rate=0.5)
    key, active = jax.random.PRNGKey(0), []
    for i in range(50):
        _, mask, _ = sample_cohort_ex(jax.random.fold_in(key, i), cfg)
        n = int(mask.sum())
        assert 1 <= n <= 16
        active.append(n)
    assert np.mean(active) < 12  # ~8 expected at rate 0.5


def test_unknown_availability_raises():
    cfg = FedConfig(algo="fedcm", num_clients=10, cohort_size=3,
                    availability="lunar")
    with pytest.raises(ValueError, match="lunar"):
        availability_log_weights(cfg)


# ----------------------------------------------------------------------
# streaming data + store mechanics
# ----------------------------------------------------------------------


def test_streaming_shards_deterministic_and_shaped():
    task = StreamingClientData(1000, dim=8, n_classes=4, n_per_client=20, seed=0)
    ids = np.array([3, 999, 41], np.int32)
    b1 = task.host_round_batches(ids, seed=7, local_steps=3, batch_size=5)
    b2 = task.host_round_batches(ids, seed=7, local_steps=3, batch_size=5)
    assert b1["x"].shape == (3, 3, 5, 8) and b1["y"].shape == (3, 3, 5)
    _assert_bitwise(b1, b2)  # same (seed, ids) → same block
    b3 = task.host_round_batches(ids, seed=8, local_steps=3, batch_size=5)
    assert not np.array_equal(b1["x"], b3["x"])

    x3, y3 = task.client_dataset(3)
    x999, _ = task.client_dataset(999)
    assert x3.shape == (20, 8) and y3.dtype == np.int32
    assert not np.array_equal(x3, x999)
    full = task.host_full_batches(ids)
    np.testing.assert_array_equal(full["x"][0], x3)
    # label skew: the dominant class cid % n_classes leads the histogram
    assert np.bincount(y3, minlength=4).argmax() == 3 % 4
    xt1, yt1 = task.test_set(100)
    xt2, yt2 = task.test_set(100)
    np.testing.assert_array_equal(xt1, xt2)
    np.testing.assert_array_equal(yt1, yt2)


def test_host_store_gather_scatter_and_packing():
    store = HostPopulationStore(1000, plane_size=4)
    assert store.gather(np.array([5, 900])).tolist() == [[0] * 4, [0] * 4]
    rows = np.arange(8, dtype=np.float32).reshape(2, 4)
    store.scatter(np.array([900, 5]), rows)
    np.testing.assert_array_equal(store.gather(np.array([5])), rows[1:])
    assert store.touched == 2 and store.nbytes == 2 * 4 * 4
    with pytest.raises(ValueError):
        store.scatter(np.array([1]), np.zeros((1, 3), np.float32))
    packed = store.to_pytree()
    assert packed["ids"].tolist() == [5, 900]  # sorted
    again = HostPopulationStore.from_pytree(packed, 1000)
    np.testing.assert_array_equal(again.gather(np.array([5, 900])),
                                  store.gather(np.array([5, 900])))


def test_host_store_requires_init_and_flat_plane():
    cfg, loss_fn, data, model = _setup("scaffold", population_store="host")
    eng = FederatedEngine(cfg, loss_fn, batch_size=8)
    state = _fresh(eng, model)
    eng.population = None  # simulate a hand-built state skipping init()
    with pytest.raises(RuntimeError, match="population store"):
        eng.run_rounds(state, data, 1)
    with pytest.raises(ValueError, match="flat"):
        FederatedEngine(replace(cfg, use_flat_plane=False), loss_fn,
                        batch_size=8)


def test_host_store_streaming_end_to_end_bounded_memory():
    # StreamingClientData + host store: run rounds at N ≫ cohort and check
    # the store only ever holds touched clients (≤ rounds × capacity)
    cfg = FedConfig(algo="scaffold", num_clients=5_000, cohort_size=4,
                    local_steps=2, participation="fixed",
                    population_store="host")
    task = StreamingClientData(cfg.num_clients, dim=8, n_classes=4, seed=0)
    model = mlp_classifier((8, 16, 4))
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    st = _fresh(eng, model)
    st, ms = eng.run_rounds(st, task, 4)
    assert st.client_states is None
    assert np.all(np.isfinite(np.asarray(ms.loss)))
    assert 0 < eng.population.touched <= 4 * cohort_capacity(cfg)


@pytest.mark.slow
def test_host_store_1e5_smoke():
    # the multidevice CI job's N=1e5 participation smoke: a store-backed
    # kernel-path zipf run must hold rounds without materializing the
    # population (device OR host)
    cfg = FedConfig(algo="scaffold", num_clients=100_000, cohort_size=20,
                    local_steps=2, participation="bernoulli",
                    availability="zipf", use_fused_kernel=True,
                    population_store="host")
    task = StreamingClientData(cfg.num_clients, dim=8, n_classes=4, seed=0)
    model = mlp_classifier((8, 16, 4))
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    st = _fresh(eng, model)
    st, ms = eng.run_rounds(st, task, 3)
    assert np.all(np.isfinite(np.asarray(ms.loss)))
    assert 0 < eng.population.touched < 5_000
