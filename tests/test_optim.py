"""Optimizer library unit tests."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.optim.optimizers import (
    adam,
    adamw,
    clip_by_global_norm,
    exponential_decay,
    momentum,
    sgd,
    warmup_cosine,
)
from repro.utils.trees import tree_add


def test_sgd_matches_closed_form():
    opt = sgd(0.1)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    s = opt.init(p)
    u, s = opt.update(g, s, p)
    np.testing.assert_allclose(np.asarray(u["w"]), [-0.05, 0.05], rtol=1e-6)


def test_adam_first_step_is_lr_signed():
    """Bias-corrected Adam's first step ≈ lr·sign(g) for eps→0."""
    opt = adam(1e-2, eps=1e-12)
    p = {"w": jnp.array([1.0, -1.0, 3.0])}
    g = {"w": jnp.array([0.3, -0.4, 0.0001])}
    s = opt.init(p)
    u, _ = opt.update(g, s, p)
    np.testing.assert_allclose(np.asarray(u["w"]), -1e-2 * np.sign(np.asarray(g["w"])), rtol=1e-4)


def test_adam_bf16_moments_close_to_f32():
    opt32 = adam(1e-3)
    opt16 = adam(1e-3, moment_dtype=jnp.bfloat16)
    p = {"w": jnp.asarray(np.random.default_rng(0).normal(size=64), jnp.float32)}
    s32, s16 = opt32.init(p), opt16.init(p)
    assert jax.tree_util.tree_leaves(s16)[1].dtype == jnp.bfloat16
    rng = np.random.default_rng(1)
    p32, p16 = p, p
    for _ in range(10):
        g = {"w": jnp.asarray(rng.normal(size=64), jnp.float32)}
        u32, s32 = opt32.update(g, s32, p32)
        u16, s16 = opt16.update(g, s16, p16)
        p32, p16 = tree_add(p32, u32), tree_add(p16, u16)
    np.testing.assert_allclose(np.asarray(p16["w"]), np.asarray(p32["w"]), atol=5e-3)


def test_momentum_accumulates():
    opt = momentum(1.0, beta=0.9)
    p = {"w": jnp.zeros(1)}
    s = opt.init(p)
    g = {"w": jnp.ones(1)}
    u1, s = opt.update(g, s, p)
    u2, s = opt.update(g, s, p)
    np.testing.assert_allclose(np.asarray(u1["w"]), [-1.0])
    np.testing.assert_allclose(np.asarray(u2["w"]), [-1.9])


def test_adamw_decays_only_matrices():
    opt = adamw(1e-2, weight_decay=0.1)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    s = opt.init(p)
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    u, _ = opt.update(g, s, p)
    assert float(jnp.max(jnp.abs(u["w"]))) > 0  # decayed
    np.testing.assert_allclose(np.asarray(u["b"]), np.zeros(2), atol=1e-12)


@given(norm=st.floats(0.1, 10.0))
@settings(max_examples=10, deadline=None)
def test_clip_by_global_norm(norm):
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((3,), -4.0)}
    clipped, pre = clip_by_global_norm(g, norm)
    total = float(jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree_util.tree_leaves(clipped))))
    assert total <= norm * 1.001
    if float(pre) <= norm:
        np.testing.assert_allclose(np.asarray(clipped["a"]), np.asarray(g["a"]), rtol=1e-5)


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    vals = [float(sched(jnp.int32(t))) for t in range(0, 100, 5)]
    assert vals[0] < vals[1]  # warming up
    assert max(vals) <= 1.0 + 1e-6
    assert vals[-1] < vals[4]  # decaying
    assert vals[-1] >= 0.1 - 1e-6  # floor


def test_exponential_decay_matches_paper_formula():
    sched = exponential_decay(0.1, 0.998)
    for t in [0, 1, 100, 4000]:
        # f32 pow accumulates ~1e-4 rel error at t=4000
        np.testing.assert_allclose(float(sched(jnp.int32(t))), 0.1 * 0.998**t, rtol=1e-3)
