"""Fault-tolerant round execution (PR-7).

Covers the three tentpole layers:

1. fault injection as pure FaultConfig data — seeded, reproducible,
   zero-rate ~ fault=None (tight tolerance; the extra traced quarantine
   ops perturb XLA's scan fusion at f32 noise level, while fault=None
   itself traces NOTHING extra and is held bitwise by the pre-existing
   trajectory suites),
2. graceful degradation — NaN/Inf quarantine equal to the fold that
   excluded the bad client (every registered algorithm), min_quorum
   skip-rounds, empty-cohort no-op (the 0/0 NaN-poisoning regression),
   host-store retry with capped backoff,
3. preemption-safe runs — atomic save_fed_run/load_fed_run snapshots
   continuing the trajectory bitwise, resident and host-store.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core.engine as engine_mod
from repro.configs.base import FaultConfig, FedConfig
from repro.checkpoint import (
    latest_step,
    load_fed_run,
    save_checkpoint,
    save_fed_run,
)
from repro.core import FederatedEngine, get_algorithm, list_algorithms
from repro.core.faults import fault_masks
from repro.data import FederatedData, StreamingClientData, make_synthetic_classification
from repro.data.population import FaultyStore, TransientStoreError
from repro.models.small import classification_loss, mlp_classifier


def _setup(algo, **kw):
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=800, n_test=8)
    model = mlp_classifier((8, 16, 4))
    base = dict(algo=algo, num_clients=10, cohort_size=3, local_steps=2,
                participation="fixed")
    base.update(kw)
    cfg = FedConfig(**base)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    return cfg, eng, data, model


def _fresh_state(eng, model):
    return eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


def _all_finite(tree):
    return all(bool(jnp.all(jnp.isfinite(l))) for l in _leaves(tree)
               if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating))


def _assert_trees_equal(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb)
    for x1, x2 in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


def _assert_trees_close(a, b, rtol=2e-5, atol=1e-6):
    for la, lb in zip(_leaves(a), _leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# 1. faults as config data
# ---------------------------------------------------------------------------

def test_zero_rate_fault_config_matches_fault_none():
    """All-zero rates inject nothing: same trajectory as fault=None up to
    scan-fusion noise (the quarantine guard's isfinite/where ops perturb
    XLA's reduction fusion inside lax.scan — values, not semantics)."""
    _, eng0, data, model = _setup("fedcm")
    st0, m0 = eng0.run_rounds(_fresh_state(eng0, model), data, 4)
    _, eng1, _, _ = _setup("fedcm", fault=FaultConfig())
    st1, m1 = eng1.run_rounds(_fresh_state(eng1, model), data, 4)
    _assert_trees_close(st0.params, st1.params, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(m0.n_active), np.asarray(m1.n_active))
    assert float(m1.n_dropped.sum()) == 0.0
    assert float(m1.n_quarantined.sum()) == 0.0
    assert float(m1.quorum_skipped.sum()) == 0.0


def test_fault_draws_are_reproducible_and_slot_independent():
    """The fault stream is keyed by (seed, absolute round, client id) —
    the same client gets the same fate regardless of cohort slot."""
    fault = FaultConfig(drop_rate=0.5, corrupt_rate=0.5, seed=3)
    ids = jnp.asarray([4, 7, 1])
    a = fault_masks(fault, 2, ids)
    b = fault_masks(fault, 2, ids)
    np.testing.assert_array_equal(np.asarray(a.drop), np.asarray(b.drop))
    np.testing.assert_array_equal(np.asarray(a.corrupt), np.asarray(b.corrupt))
    # permute the cohort: per-client fates permute with it
    perm = jnp.asarray([1, 7, 4])
    c = fault_masks(fault, 2, perm)
    np.testing.assert_array_equal(np.asarray(a.drop)[[2, 1, 0]], np.asarray(c.drop))
    # a different round or seed redraws
    d = fault_masks(fault, 3, ids)
    e = fault_masks(FaultConfig(drop_rate=0.5, corrupt_rate=0.5, seed=4), 2, ids)
    assert (not np.array_equal(np.asarray(a.drop), np.asarray(d.drop))
            or not np.array_equal(np.asarray(a.corrupt), np.asarray(d.corrupt))
            or not np.array_equal(np.asarray(a.drop), np.asarray(e.drop)))


@pytest.mark.parametrize("kernel", [False, True])
def test_lossy_uplink_run_stays_finite(kernel):
    """The acceptance scenario: 20% drops + 1% NaN corruption, fedcm —
    the run completes finite on the jnp and kernel paths."""
    fault = FaultConfig(drop_rate=0.2, corrupt_rate=0.01, seed=0)
    _, eng, data, model = _setup("fedcm", num_clients=20, cohort_size=8,
                                 participation="bernoulli", fault=fault,
                                 min_quorum=2, use_fused_kernel=kernel)
    st, ms = eng.run_rounds(_fresh_state(eng, model), data, 8)
    assert _all_finite(st.params)
    assert _all_finite(st.server)
    assert float(ms.n_dropped.sum()) > 0
    assert np.all(np.isfinite(np.asarray(ms.loss)))


# ---------------------------------------------------------------------------
# 2. graceful degradation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", list_algorithms())
def test_quarantine_equals_excluding_the_client(algo, monkeypatch):
    """A NaN-corrupted uplink, quarantined, folds IDENTICALLY to the same
    round with that client dropped outright — for EVERY registered
    algorithm (the registry parametrizes).  Run B reroutes the corrupt
    mask into the drop mask before injection, so the same per-client
    fault stream marks the same clients; equality then says quarantine
    zeroing removed every trace of the poisoned rows from params, server
    planes, and client state."""
    fault = FaultConfig(corrupt_rate=0.5, corrupt_mode="nan", seed=5)
    _, eng_a, data, model = _setup(algo, fault=fault)
    st_a, ms_a = eng_a.run_rounds(_fresh_state(eng_a, model), data, 3)
    assert float(ms_a.n_quarantined.sum()) > 0  # the stream did corrupt
    assert _all_finite(st_a.params) and _all_finite(st_a.server)

    orig = engine_mod.fault_masks

    def rerouted(f, t, ids):
        plan = orig(f, t, ids)
        return plan._replace(drop=plan.corrupt,
                             corrupt=jnp.zeros_like(plan.corrupt))

    monkeypatch.setattr(engine_mod, "fault_masks", rerouted)
    # drop_rate>0 opens the engine's (python-level) drop branch; the
    # rerouted plan then discards the real drop draws — the corrupt
    # stream is keyed independently, so B marks exactly A's clients
    fault_b = FaultConfig(drop_rate=0.5, corrupt_rate=0.5,
                          corrupt_mode="nan", seed=5)
    _, eng_b, _, _ = _setup(algo, fault=fault_b)
    st_b, ms_b = eng_b.run_rounds(_fresh_state(eng_b, model), data, 3)
    np.testing.assert_array_equal(np.asarray(ms_a.n_active),
                                  np.asarray(ms_b.n_active))
    _assert_trees_equal(st_a.params, st_b.params)
    _assert_trees_equal(st_a.server, st_b.server)
    if get_algorithm(algo).needs_client_state:
        _assert_trees_equal(st_a.client_states, st_b.client_states)


@pytest.mark.parametrize("corrupt_mode", ["inf", "noise"])
def test_other_corruption_modes_stay_finite(corrupt_mode):
    fault = FaultConfig(corrupt_rate=0.4, corrupt_mode=corrupt_mode,
                        noise_scale=100.0, seed=1,
                        quarantine_norm_mult=3.0 if corrupt_mode == "noise" else 0.0)
    _, eng, data, model = _setup("fedcm", fault=fault)
    st, ms = eng.run_rounds(_fresh_state(eng, model), data, 4)
    assert _all_finite(st.params)
    if corrupt_mode == "inf":
        assert float(ms.n_quarantined.sum()) > 0


def test_min_quorum_skips_the_fold():
    """min_quorum above the cohort size: every fold skips, params carry
    BITWISE unchanged, and the counter reports it."""
    fault = FaultConfig(drop_rate=0.0, seed=0)
    _, eng, data, model = _setup("fedcm", fault=fault, min_quorum=99)
    st0 = _fresh_state(eng, model)
    p0 = jax.tree_util.tree_map(lambda l: np.asarray(l), st0.params)
    st, ms = eng.run_rounds(st0, data, 3)
    assert np.all(np.asarray(ms.quorum_skipped) == 1.0)
    _assert_trees_equal(p0, st.params)
    _assert_trees_equal(st.server.momentum,
                        jax.tree_util.tree_map(jnp.zeros_like, st.server.momentum))


@pytest.mark.parametrize("kernel", [False, True])
def test_empty_cohort_round_is_a_guarded_noop(kernel):
    """The empty-cohort hazard (satellite a): an all-dropped cohort used
    to masked-mean 0/0 → NaN params.  With allow_empty_cohort the round
    must be a finite no-op on BOTH the jnp and kernel paths."""
    _, eng, data, model = _setup("fedcm", dropout_rate=1.0,
                                 allow_empty_cohort=True,
                                 use_fused_kernel=kernel)
    st0 = _fresh_state(eng, model)
    p0 = jax.tree_util.tree_map(lambda l: np.asarray(l), st0.params)
    st, ms = eng.run_rounds(st0, data, 2)
    assert np.all(np.asarray(ms.n_active) == 0.0)
    assert _all_finite(st.params)
    _assert_trees_equal(p0, st.params)  # no 0/0 poison, no partial fold


def test_allow_empty_cohort_flag_toggles_the_guard():
    """dropout_rate=1.0: the legacy guard keeps one client per round;
    allow_empty_cohort=True lets the cohort empty entirely."""
    _, eng_legacy, data, model = _setup("fedcm", dropout_rate=1.0)
    _, ms = eng_legacy.run_rounds(_fresh_state(eng_legacy, model), data, 3)
    assert np.all(np.asarray(ms.n_active) == 1.0)
    _, eng_empty, _, _ = _setup("fedcm", dropout_rate=1.0,
                                allow_empty_cohort=True)
    _, ms2 = eng_empty.run_rounds(_fresh_state(eng_empty, model), data, 3)
    assert np.all(np.asarray(ms2.n_active) == 0.0)


def _store_setup(algo, fault, num_clients=64):
    cfg = FedConfig(algo=algo, num_clients=num_clients, cohort_size=8,
                    local_steps=2, population_store="host", fault=fault)
    data = StreamingClientData(num_clients, dim=8, n_classes=4, seed=0)
    model = mlp_classifier((8, 16, 4))
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    st = eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
    return eng, data, st


def test_store_transient_failures_are_retried():
    """FaultyStore raises TransientStoreError with host-side probability;
    the engine retries with capped backoff and counts the attempts.
    (seed=2: the chaos stream fails within the first rounds — seed=1's
    first 16 draws happen to all pass.)"""
    fault = FaultConfig(store_failure_rate=0.3, store_backoff_base=0.0, seed=2)
    eng, data, st = _store_setup("scaffold", fault)
    assert isinstance(eng.population, FaultyStore)
    st, ms = eng.run_rounds_store(st, data, 5)
    assert _all_finite(st.params)
    assert float(ms.n_retries.sum()) > 0


def test_store_retry_exhaustion_reraises():
    fault = FaultConfig(store_failure_rate=1.0, store_max_retries=2,
                        store_backoff_base=0.0, seed=0)
    eng, data, st = _store_setup("scaffold", fault)
    with pytest.raises(TransientStoreError):
        eng.run_rounds_store(st, data, 1)


def test_retries_never_change_the_math():
    """A run that needed retries is bitwise-equal to one that didn't:
    same config, chaos on vs off, identical trajectories."""
    fault_on = FaultConfig(drop_rate=0.2, store_failure_rate=0.3,
                           store_backoff_base=0.0, seed=2)
    fault_off = FaultConfig(drop_rate=0.2, store_failure_rate=0.0, seed=2)
    eng_a, data, st_a = _store_setup("scaffold", fault_on)
    st_a, ms_a = eng_a.run_rounds_store(st_a, data, 4)
    assert float(ms_a.n_retries.sum()) > 0
    eng_b, _, st_b = _store_setup("scaffold", fault_off)
    st_b, ms_b = eng_b.run_rounds_store(st_b, data, 4)
    assert float(ms_b.n_retries.sum()) == 0.0
    _assert_trees_equal(st_a.params, st_b.params)
    np.testing.assert_array_equal(
        np.asarray(eng_a.population.inner.to_pytree()["rows"]),
        np.asarray(eng_b.population.to_pytree()["rows"]))


# ---------------------------------------------------------------------------
# 3. preemption-safe runs
# ---------------------------------------------------------------------------

def test_save_checkpoint_publishes_atomically(tmp_path):
    """No .tmp residue after publish — the rename is the commit point."""
    save_checkpoint(str(tmp_path), 3, {"w": jnp.ones((4,))})
    names = os.listdir(tmp_path)
    assert "step_3.msgpack" in names
    assert not any(n.endswith(".tmp") for n in names)


def test_save_fed_run_roundtrip_resident(tmp_path):
    _, eng, data, model = _setup("fedcm")
    st, _ = eng.run_rounds(_fresh_state(eng, model), data, 2)
    save_fed_run(str(tmp_path), 2, st, meta={"note": "x"})
    restored, pop, _res, meta = load_fed_run(str(tmp_path), 2, st)
    assert meta["step"] == 2 and meta["note"] == "x" and pop is None
    _assert_trees_equal(st, restored)


def test_kill_and_resume_is_bitwise_resident():
    """6 straight rounds == 3 rounds + snapshot + restore + 3 rounds, on
    the fused scan — the trajectory continues bitwise through the
    checkpoint boundary."""
    import tempfile

    fault = FaultConfig(drop_rate=0.2, seed=1)
    _, eng, data, model = _setup("fedcm", fault=fault)
    st_full, _ = eng.run_rounds(_fresh_state(eng, model), data, 3)
    st_full, _ = eng.run_rounds(st_full, data, 3)

    st_half, _ = eng.run_rounds(_fresh_state(eng, model), data, 3)
    with tempfile.TemporaryDirectory() as d:
        save_fed_run(d, 3, st_half)
        assert latest_step(d) == 3
        st_resumed, pop, _res, _ = load_fed_run(d, None, st_half)
    st_resumed, _ = eng.run_rounds(st_resumed, data, 3)
    _assert_trees_equal(st_full, st_resumed)


def test_kill_and_resume_is_bitwise_host_store(tmp_path):
    """Same through the host population store: the snapshot carries the
    packed rows, the restore rebuilds the store, scaffold's c_i planes
    continue bitwise."""
    fault = FaultConfig(drop_rate=0.1, seed=0)
    eng_a, data, st_a = _store_setup("scaffold", fault)
    st_a, _ = eng_a.run_rounds_store(st_a, data, 4)

    eng_b, _, st_b = _store_setup("scaffold", fault)
    st_b, _ = eng_b.run_rounds_store(st_b, data, 2)
    save_fed_run(str(tmp_path), 2, st_b,
                 population=getattr(eng_b.population, "inner", eng_b.population))
    # a FRESH engine (the resumed process) restores state + store
    eng_c, _, st_c = _store_setup("scaffold", fault)
    st_c, pop, _res, meta = load_fed_run(str(tmp_path), None, st_c,
                                         num_clients=eng_c.cfg.num_clients)
    assert meta["step"] == 2 and pop is not None
    getattr(eng_c.population, "inner", eng_c.population)._rows = pop._rows
    st_c, _ = eng_c.run_rounds_store(st_c, data, 2)
    _assert_trees_equal(st_a.params, st_c.params)
    np.testing.assert_array_equal(
        np.asarray(getattr(eng_a.population, "inner", eng_a.population)
                   .to_pytree()["rows"]),
        np.asarray(getattr(eng_c.population, "inner", eng_c.population)
                   .to_pytree()["rows"]))
