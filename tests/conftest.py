"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py forces 512 placeholder devices.

``hypothesis`` is an optional dev dependency.  Several test modules import
it at module scope (``from hypothesis import given, ...``), so a plain
missing-module error would abort collection of the *entire* suite.  When it
is absent we install a minimal stub into ``sys.modules`` whose ``@given``
decorator turns each property-based test into an auto-skip; every other
test in those modules still collects and runs.
"""
import sys
import types

import jax
import numpy as np
import pytest

try:
    from hypothesis import settings

    HAVE_HYPOTHESIS = True
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    HAVE_HYPOTHESIS = False

    def _install_hypothesis_stub():
        class _Anything:
            """Placeholder strategy object: accepts any call/attr chain."""

            def __call__(self, *a, **k):
                return self

            def __getattr__(self, name):
                return self

        class _StubSettings:
            def __init__(self, *a, **k):
                pass

            def __call__(self, fn):
                return fn

            @staticmethod
            def register_profile(*a, **k):
                pass

            @staticmethod
            def load_profile(*a, **k):
                pass

        def _given(*a, **k):
            def deco(fn):
                @pytest.mark.skip(reason="hypothesis not installed")
                def skipped():
                    pass

                skipped.__name__ = fn.__name__
                skipped.__doc__ = fn.__doc__
                return skipped

            return deco

        mod = types.ModuleType("hypothesis")
        mod.given = _given
        mod.settings = _StubSettings
        mod.assume = lambda *a, **k: True
        mod.example = lambda *a, **k: (lambda fn: fn)
        mod.HealthCheck = _Anything()
        st_mod = types.ModuleType("hypothesis.strategies")

        def _strategy_factory(*a, **k):
            return _Anything()

        st_mod.__getattr__ = lambda name: _strategy_factory
        mod.strategies = st_mod
        sys.modules["hypothesis"] = mod
        sys.modules["hypothesis.strategies"] = st_mod

    _install_hypothesis_stub()

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
