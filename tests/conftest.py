"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import numpy as np
import pytest
from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
