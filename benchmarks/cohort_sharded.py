"""Cohort-parallel engine throughput: rounds/s vs devices on the client axis.

Shards the cohort over an emulated ``("clients",)`` mesh (this module sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` BEFORE importing
jax — run it as its own process, which is exactly how ``benchmarks.run``/
CI invoke it) and measures ``FederatedEngine`` rounds/s at 1/2/4/8 mesh
devices against the single-device flat+kernel baseline, sync
(``run_rounds``) and async (``run_rounds_async``, D=2 — the ring gives the
fold's reduce-scatter a round of compute to hide behind).

Three workloads, three regimes:

* ``update_bound`` — the headline shape of benchmarks/fused_rounds.py
  (deep-narrow 202-leaf MLP, C=16, K=1).  Its round is an op-LATENCY
  chain (hundreds of tiny ops, per-op work ~nothing), and sharding
  clients does not shorten a latency chain — each device still executes
  the full per-round op sequence, so the ratio sits at ~1.0x.  The number
  documents that honestly; this is the regime where a real multi-host
  mesh wins by hiding the collective, not by splitting compute.
* ``update_bound_c64`` — the same deep-narrow model at cohort 64: enough
  per-op work that splitting it shows (measured ~1.5x at 8 devices on the
  2-core container).
* ``cohort_scaled`` — per-client work scaled until the round is
  compute-bound (wider MLP, C=32, B=64).  Here client sharding is real
  parallel work AND it shrinks each device's vmap width and activation
  working set, which the single-device flat+kernel baseline pays for
  superlinearly — measured ≥2x (typically well above) at 8 emulated
  devices vs the 1-device baseline, the acceptance number this benchmark
  tracks.  The artifact records ``cpu_count`` for context.

Artifact: benchmarks/artifacts/cohort_sharded.json — rounds/s per
(workload, n_devices), speedup vs the 1-device baseline, and the async-D2
overlap ratio at the widest mesh.  ``benchmarks/fused_rounds.py`` folds
this file (when present) into the top-level BENCH_fused_rounds.json
trajectory summary.

    PYTHONPATH=src python -m benchmarks.cohort_sharded [--rounds N]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time
from pathlib import Path

import jax

from repro.configs.base import FedConfig
from repro.core import FederatedEngine
from repro.data import FederatedData, make_synthetic_classification
from repro.launch.mesh import make_cohort_mesh
from repro.models.small import classification_loss, mlp_classifier

ARTIFACT = Path(__file__).resolve().parent / "artifacts" / "cohort_sharded.json"

WORKLOADS = {
    # the fused_rounds headline shape: latency-bound, documents the honest
    # non-win of client sharding on an op-latency chain
    "update_bound": dict(dims=(32,) + (16,) * 100 + (10,), cohort=16, K=1, B=8,
                         clients=64),
    # same model, cohort scaled to 64: per-op work large enough to split
    "update_bound_c64": dict(dims=(32,) + (16,) * 100 + (10,), cohort=64, K=1,
                             B=32, clients=128, sweep=False),
    # per-client work scaled until the round is compute-bound — the regime
    # client sharding is FOR (the acceptance ≥2x-at-8-devices number)
    "cohort_scaled": dict(dims=(64,) + (256,) * 4 + (10,), cohort=32, K=1, B=64,
                          clients=64),
}


def _block(state):
    jax.block_until_ready(jax.tree_util.tree_leaves(state.params))


def _measure_workload(name, dims, cohort, K, B, clients, rounds, alts, quiet,
                      device_counts, sweep=True):
    if not sweep:  # cheap workloads sweep every count; others baseline-vs-widest
        device_counts = [max(device_counts)] if device_counts else []
    cfg = FedConfig(algo="fedcm", num_clients=clients, cohort_size=cohort,
                    local_steps=K, participation="fixed",
                    use_fused_kernel=True)
    x, y, *_ = make_synthetic_classification(
        n_classes=10, dim=dims[0], n_train=cohort * 200, n_test=10
    )
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    model = mlp_classifier(dims)
    loss_fn = classification_loss(model.apply)

    def make_runner(nd, depth=1):
        mesh = make_cohort_mesh(nd) if nd > 0 else None
        eng = FederatedEngine(cfg, loss_fn, batch_size=B, cohort_mesh=mesh)

        def fresh():
            return eng.init(model.init(jax.random.PRNGKey(0)),
                            jax.random.PRNGKey(1))

        if depth > 1:
            return lambda: eng.run_rounds_async(fresh(), data, rounds,
                                                pipeline_depth=depth)
        return lambda: eng.run_rounds(fresh(), data, rounds)

    runners = {"1dev_baseline": make_runner(0)}
    for nd in device_counts:
        runners[f"shard_{nd}dev"] = make_runner(nd)
    widest = max(device_counts) if device_counts else 0
    if widest > 1:
        runners[f"shard_{widest}dev_async_d2"] = make_runner(widest, depth=2)

    for r in runners.values():  # compile outside the timed region
        st, _ = r()
        _block(st)
    times = {k: [] for k in runners}
    for _ in range(alts):  # interleaved: slow drift cannot bias one path
        for k, r in runners.items():
            t0 = time.perf_counter()
            st, _ = r()
            _block(st)
            times[k].append(time.perf_counter() - t0)
    best = {k: min(v) for k, v in times.items()}

    base = best["1dev_baseline"]
    result = {
        "workload": {
            "algo": cfg.algo, "num_clients": clients, "cohort_size": cohort,
            "local_steps": K, "batch_size": B,
            "model": f"mlp {len(dims) - 1} layers ({2 * (len(dims) - 1)} leaves)",
            "rounds": rounds, "timing": f"interleaved min of {alts}",
            "path": "flat + fused kernels",
        },
        "baseline_rounds_per_s": round(rounds / base, 2),
    }
    for k, s in best.items():
        if k == "1dev_baseline":
            continue
        result[f"{k}_rounds_per_s"] = round(rounds / s, 2)
        result[f"{k}_speedup"] = round(base / s, 2)
    if not quiet:
        print(f"== cohort_sharded/{name} ({result['workload']['model']}, "
              f"C={cohort}, K={K}, B={B}) ==")
        print(f"  1-dev baseline: {base:.3f}s  "
              f"({result['baseline_rounds_per_s']} rounds/s)")
        for k in runners:
            if k == "1dev_baseline":
                continue
            print(f"  {k:<22} {best[k]:.3f}s  "
                  f"({result[f'{k}_rounds_per_s']} rounds/s, "
                  f"{result[f'{k}_speedup']}x)")
    return result


def main(rounds: int = 20, alts: int = 3, quiet: bool = False) -> dict:
    from benchmarks.common import git_rev

    n_dev = len(jax.devices())
    device_counts = [d for d in (1, 2, 4, 8) if d <= n_dev]
    result = {
        # the trajectory summary only folds this artifact into a row for
        # the SAME rev — a checked-in artifact from an earlier commit must
        # not masquerade as the current one's numbers
        "rev": git_rev(),
        "devices_visible": n_dev,
        "cpu_count": os.cpu_count(),
        "device_counts": device_counts,
    }
    for name, wl in WORKLOADS.items():
        result[name] = _measure_workload(
            name, rounds=rounds, alts=alts, quiet=quiet,
            device_counts=device_counts, **wl
        )
    ARTIFACT.parent.mkdir(parents=True, exist_ok=True)
    ARTIFACT.write_text(json.dumps(result, indent=1))
    if not quiet:
        print(f"  (artifact: {ARTIFACT.name})")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--alts", type=int, default=3,
                    help="interleaved timing repetitions per path")
    args = ap.parse_args()
    main(rounds=args.rounds, alts=args.alts)
