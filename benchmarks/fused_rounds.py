"""Round-throughput: fused run_rounds scan vs per-round jit dispatch.

The paper's experiments are hundreds-to-thousands of *cheap* rounds
(Table 1: 4000 rounds of a small CNN), so round dispatch overhead — one
jit call + host-side cohort sampling + metric device→host syncs per round —
dominates wall clock on the synthetic workload.  This benchmark measures
the same trajectory both ways:

* sequential: ``engine.run_round`` × N (one jit dispatch per round),
* fused:      ``engine.run_rounds(state, data, N)`` (ONE lax.scan program,
  cohort sampling + minibatch gathers on-device, donated state).

Artifact: benchmarks/artifacts/fused_rounds.json with per-path seconds,
rounds/s, and the speedup factor.  Run via ``python -m benchmarks.run`` or
directly: ``PYTHONPATH=src python -m benchmarks.fused_rounds [--rounds N]``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

from repro.configs.base import FedConfig
from repro.core import FederatedEngine
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

ARTIFACT = Path(__file__).resolve().parent / "artifacts" / "fused_rounds.json"


def _block(state):
    jax.block_until_ready(jax.tree_util.tree_leaves(state.params))


def main(rounds: int = 100, quiet: bool = False) -> dict:
    cfg = FedConfig(algo="fedcm", num_clients=64, cohort_size=8, local_steps=5,
                    participation="fixed")
    x, y, *_ = make_synthetic_classification(
        n_classes=10, dim=32, n_train=6400, n_test=10
    )
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    model = mlp_classifier((32, 64, 64, 10))
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=32)

    def fresh():
        return eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))

    # --- warm both paths (compile outside the timed region) ---
    st = fresh()
    st, _ = eng.run_round(st, data)
    _block(st)
    st, _ = eng.run_rounds(fresh(), data, rounds)
    _block(st)

    # --- sequential: one dispatch per round ---
    st = fresh()
    t0 = time.perf_counter()
    for _ in range(rounds):
        st, _ = eng.run_round(st, data)
    _block(st)
    seq_s = time.perf_counter() - t0

    # --- fused: one scanned program ---
    st = fresh()
    t0 = time.perf_counter()
    st, _ = eng.run_rounds(st, data, rounds)
    _block(st)
    fused_s = time.perf_counter() - t0

    result = {
        "workload": {
            "algo": cfg.algo, "num_clients": cfg.num_clients,
            "cohort_size": cfg.cohort_size, "local_steps": cfg.local_steps,
            "batch_size": 32, "model": "mlp 32-64-64-10", "rounds": rounds,
        },
        "sequential_s": round(seq_s, 4),
        "fused_s": round(fused_s, 4),
        "sequential_rounds_per_s": round(rounds / seq_s, 2),
        "fused_rounds_per_s": round(rounds / fused_s, 2),
        "speedup": round(seq_s / fused_s, 2),
    }
    ARTIFACT.parent.mkdir(parents=True, exist_ok=True)
    ARTIFACT.write_text(json.dumps(result, indent=1))
    if not quiet:
        print(f"  sequential: {seq_s:.3f}s  ({result['sequential_rounds_per_s']} rounds/s)")
        print(f"  fused:      {fused_s:.3f}s  ({result['fused_rounds_per_s']} rounds/s)")
        print(f"  speedup:    {result['speedup']}x  (artifact: {ARTIFACT.name})")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=100)
    args = ap.parse_args()
    main(rounds=args.rounds)
