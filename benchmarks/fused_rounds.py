"""Round-throughput: per-round dispatch vs fused scan vs flat-plane engine.

The paper's experiments are hundreds-to-thousands of *cheap* rounds
(Table 1: 4000 rounds of a small CNN), so per-round overheads — jit
dispatch, host-side cohort sampling, per-leaf tree_map op chains in the
aggregate/server phase — dominate wall clock.  This benchmark measures the
same trajectory three ways:

* sequential: ``engine.run_round`` × N (one jit dispatch per round),
* tree-fused (the PR-1 engine, ``use_flat_plane=False``): ONE lax.scan
  program, but the whole update phase is per-leaf tree_map chains — one
  masked tensordot per leaf per uplink plane (including the zeros
  state/extra planes stateless algorithms still materialize), per-leaf
  server updates, per-leaf metric norms,
* flat-fused (this PR's default): the same local-step scan, but every
  round-scope reduction lands on ONE ravelled (P,) buffer — a single
  contraction per uplink plane, a fused flat server step, flat norms, and
  no zeros planes at all.

Three workloads, all in the artifact:

* ``update_bound`` (headline): deep-narrow MLP — 202 parameter leaves, the
  leaf census of a ResNet/transformer-class model — with K=1 local step.
  The round is
  round-machinery-bound (broadcast → 1 grad → aggregate → server), which
  is the regime the flat plane targets: for production-scale models the
  update phase is HBM-bandwidth-bound at any K, and on CPU this leaf-rich
  shape is its faithful stand-in.  The acceptance bar (flat ≥ 1.3× the
  PR-1 tree path) is measured here.
* ``paper_scaled`` (PR-1's original shape): 3-layer MLP, K=5, B=32 —
  local-grad-bound; flat ≈ tree by construction (the local scan is the
  same leaf-form code in both engines) and the number documents that the
  refactor costs nothing where it cannot win.
* ``async_pipeline``: the update-bound shape through the overlapping-cohort
  engine (``run_rounds_async``, ``scan_unroll=2`` — the ring boundary
  amortizes across an unrolled pair; the sync scan has no such boundary)
  at pipeline depth D ∈ {1, 2, 4} vs the sync ``run_rounds`` scan.  On
  one device the pipeline cannot overlap anything physically — the number
  documents that carrying the depth-D ring of in-flight cohort uplinks
  costs ~nothing per round (the acceptance bar: D=2 no slower than sync,
  judged on the drift-robust ``*_vs_sync_median`` pairwise ratio — on a
  shared 2-core container single ratios swing ±8%), so the mode is free
  until a multi-host mesh gives the overlap something to hide.
* ``algo_sweep``: rounds/s for EVERY registered algorithm
  (``repro.core.list_algorithms``) on the flat+kernel path — the per-PR
  record that each spec's declarative routing (direction row →
  ``fed_direction``, fold rows → ``server_update``, pure post-steps)
  actually executes, and what each costs relative to fedcm.  A spec that
  silently falls off the kernel route shows up here as an outlier.
* ``uplink_compression``: rounds/s + wire accounting per uplink
  compression kind (none/int8/bf16/topk) on the fused dequant-fold
  route — per-client bytes/round, the f32-relative reduction, and the
  async ring's per-slot in-flight bytes (the ring carries the compressed
  representation, so in-flight memory shrinks with the wire).
* ``store_prefetch``: the host-store loop synchronous vs double-buffered
  (``cfg.store_prefetch``) — what overlapping the next cohort's store
  gather + host batch build with the current round's device step buys.

Timing is interleaved min-of-N (alternating engines) so slow drift on a
shared host cannot bias one path.  Artifact:
benchmarks/artifacts/fused_rounds.json with per-path seconds, rounds/s,
the fused-vs-sequential speedup, and the flat-vs-tree speedup per
workload.  Every run also appends a rounds/s-per-workload row (keyed by
git rev, folding in benchmarks/cohort_sharded.py's artifact when present
— that sweep needs its own multi-device process) to the TOP-LEVEL
``BENCH_fused_rounds.json`` — the per-PR perf trajectory CI uploads.
Run via ``python -m benchmarks.run`` or directly:
``PYTHONPATH=src python -m benchmarks.fused_rounds [--rounds N]``.
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from pathlib import Path

import jax

from repro.configs.base import FedConfig
from repro.core import FederatedEngine, list_algorithms
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

ARTIFACT = Path(__file__).resolve().parent / "artifacts" / "fused_rounds.json"
#: the cohort-parallel sweep writes its own artifact (it needs a multi-
#: device process: benchmarks/cohort_sharded.py sets XLA_FLAGS pre-import);
#: when present it is folded into the trajectory summary below
COHORT_ARTIFACT = Path(__file__).resolve().parent / "artifacts" / "cohort_sharded.json"
#: the participation scenario harness (host-store population engine) also
#: writes a rev-stamped artifact; folded into the trajectory when current
PARTICIPATION_ARTIFACT = (
    Path(__file__).resolve().parent / "artifacts" / "participation_robustness.json"
)
#: convergence-vs-fault-rate curves (fault-injected engine, PR-7); folded
#: into the trajectory when current
FAULT_ARTIFACT = (
    Path(__file__).resolve().parent / "artifacts" / "fault_tolerance.json"
)
#: convergence-vs-uplink-bits curves (compressed wire engine); folded
#: into the trajectory when current
BITS_ARTIFACT = (
    Path(__file__).resolve().parent / "artifacts" / "convergence_bits.json"
)
#: the fleet-smoke job's per-round telemetry JSONL (repro.fleet): when a
#: `fed_train --serve` run at this rev wrote one here, its per-round
#: rounds/s series + hot-swap summary fold into the trajectory
FLEET_ARTIFACT = (
    Path(__file__).resolve().parent / "artifacts" / "fleet_telemetry.jsonl"
)
#: top-level per-PR perf trajectory: rounds/s per workload, one entry per
#: commit — the diffable history CI uploads (and the repo carries)
BENCH_SUMMARY = Path(__file__).resolve().parents[1] / "BENCH_fused_rounds.json"

WORKLOADS = {
    # dims, cohort, local_steps, batch — see module docstring
    "update_bound": dict(dims=(32,) + (16,) * 100 + (10,), cohort=16, K=1, B=8),
    "paper_scaled": dict(dims=(32, 64, 64, 10), cohort=8, K=5, B=32),
}


def _block(state):
    jax.block_until_ready(jax.tree_util.tree_leaves(state.params))


def _measure(name, dims, cohort, K, B, rounds, alts, quiet):
    cfg = FedConfig(algo="fedcm", num_clients=64, cohort_size=cohort,
                    local_steps=K, participation="fixed")
    x, y, *_ = make_synthetic_classification(
        n_classes=10, dim=dims[0], n_train=6400, n_test=10
    )
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    model = mlp_classifier(dims)
    loss_fn = classification_loss(model.apply)
    eng_flat = FederatedEngine(cfg, loss_fn, batch_size=B)
    eng_tree = FederatedEngine(replace(cfg, use_flat_plane=False), loss_fn,
                               batch_size=B)

    def fresh(eng):
        return eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))

    # --- warm every path (compile outside the timed region) ---
    st, _ = eng_flat.run_round(fresh(eng_flat), data)
    _block(st)
    for e in (eng_flat, eng_tree):
        st, _ = e.run_rounds(fresh(e), data, rounds)
        _block(st)

    # --- sequential: one dispatch per round (timed once; its gap is 2×+) ---
    t0 = time.perf_counter()
    st = fresh(eng_flat)
    for _ in range(rounds):
        st, _ = eng_flat.run_round(st, data)
    _block(st)
    seq_s = time.perf_counter() - t0

    # --- fused paths: interleaved min-of-N, drift-robust ---
    times = {"flat": [], "tree": []}
    for _ in range(alts):
        for key, e in (("flat", eng_flat), ("tree", eng_tree)):
            t0 = time.perf_counter()
            st, _ = e.run_rounds(fresh(e), data, rounds)
            _block(st)
            times[key].append(time.perf_counter() - t0)
    flat_s, tree_s = min(times["flat"]), min(times["tree"])

    result = {
        "workload": {
            "algo": cfg.algo, "num_clients": cfg.num_clients,
            "cohort_size": cohort, "local_steps": K, "batch_size": B,
            "model": f"mlp {len(dims) - 1} layers ({2 * (len(dims) - 1)} leaves)",
            "rounds": rounds, "timing": f"interleaved min of {alts}",
        },
        "sequential_s": round(seq_s, 4),
        "tree_fused_s": round(tree_s, 4),
        "flat_fused_s": round(flat_s, 4),
        "sequential_rounds_per_s": round(rounds / seq_s, 2),
        "tree_fused_rounds_per_s": round(rounds / tree_s, 2),
        "flat_fused_rounds_per_s": round(rounds / flat_s, 2),
        "speedup": round(seq_s / flat_s, 2),
        "flat_vs_tree_speedup": round(tree_s / flat_s, 2),
    }
    if not quiet:
        print(f"== {name} ({result['workload']['model']}, C={cohort}, K={K}) ==")
        print(f"  sequential:  {seq_s:.3f}s  ({result['sequential_rounds_per_s']} rounds/s)")
        print(f"  tree-fused:  {tree_s:.3f}s  ({result['tree_fused_rounds_per_s']} rounds/s)")
        print(f"  flat-fused:  {flat_s:.3f}s  ({result['flat_fused_rounds_per_s']} rounds/s)")
        print(f"  fused vs sequential: {result['speedup']}x   "
              f"flat vs tree: {result['flat_vs_tree_speedup']}x")
    return result


def _measure_async(rounds, alts, quiet, depths=(1, 2, 4), scan_unroll=2):
    """Sync run_rounds vs run_rounds_async at D ∈ depths, update-bound shape.

    Reports two ratios per depth: ``*_vs_sync`` from interleaved min-of-N
    (comparable to the other workloads) and ``*_vs_sync_median`` — the
    median of per-alternation sync/async PAIRWISE ratios, which cancels
    the slow load drift of a shared host much better (each alternation
    measures the two back-to-back) and is the acceptance-bar number.
    """
    wl = WORKLOADS["update_bound"]
    dims, cohort, K, B = wl["dims"], wl["cohort"], wl["K"], wl["B"]
    cfg = FedConfig(algo="fedcm", num_clients=64, cohort_size=cohort,
                    local_steps=K, participation="fixed")
    x, y, *_ = make_synthetic_classification(
        n_classes=10, dim=dims[0], n_train=6400, n_test=10
    )
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    model = mlp_classifier(dims)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=B)

    def fresh():
        return eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))

    runners = {"sync": lambda: eng.run_rounds(fresh(), data, rounds)}
    for d in depths:
        runners[f"async_d{d}"] = (
            lambda d=d: eng.run_rounds_async(fresh(), data, rounds,
                                             pipeline_depth=d,
                                             scan_unroll=scan_unroll)
        )
    for r in runners.values():  # warm/compile outside the timed region
        st, _ = r()
        _block(st)
    times = {k: [] for k in runners}
    for _ in range(alts):  # interleaved, drift-robust
        for k, r in runners.items():
            t0 = time.perf_counter()
            st, _ = r()
            _block(st)
            times[k].append(time.perf_counter() - t0)
    best = {k: min(v) for k, v in times.items()}
    result = {
        "workload": {
            "algo": cfg.algo, "num_clients": cfg.num_clients,
            "cohort_size": cohort, "local_steps": K, "batch_size": B,
            "model": f"mlp {len(dims) - 1} layers ({2 * (len(dims) - 1)} leaves)",
            "rounds": rounds, "timing": f"interleaved min/median-pairwise of {alts}",
            "pipeline_depths": list(depths), "scan_unroll": scan_unroll,
        },
        "sync_s": round(best["sync"], 4),
        "sync_rounds_per_s": round(rounds / best["sync"], 2),
    }
    for d in depths:
        s = best[f"async_d{d}"]
        pairwise = sorted(sy / a for sy, a in zip(times["sync"], times[f"async_d{d}"]))
        med = pairwise[len(pairwise) // 2]
        result[f"async_d{d}_s"] = round(s, 4)
        result[f"async_d{d}_rounds_per_s"] = round(rounds / s, 2)
        result[f"async_d{d}_vs_sync"] = round(best["sync"] / s, 2)
        result[f"async_d{d}_vs_sync_median"] = round(med, 2)
    if not quiet:
        print(f"== async_pipeline ({result['workload']['model']}, C={cohort}, "
              f"K={K}, unroll={scan_unroll}) ==")
        print(f"  sync:        {best['sync']:.3f}s  ({result['sync_rounds_per_s']} rounds/s)")
        for d in depths:
            print(f"  async D={d}:   {best[f'async_d{d}']:.3f}s  "
                  f"({result[f'async_d{d}_rounds_per_s']} rounds/s, "
                  f"{result[f'async_d{d}_vs_sync']}x min / "
                  f"{result[f'async_d{d}_vs_sync_median']}x median vs sync)")
    return result


def _measure_algo_sweep(rounds, quiet, dims=(32, 64, 64, 10), cohort=8, K=2, B=16):
    """rounds/s per REGISTERED algorithm, flat plane + fused kernels.

    One timed fused scan per algorithm (compile excluded) on a small
    shared shape — the point is per-algorithm relative cost and that the
    registry-driven kernel routing executes for every spec, not absolute
    throughput (the other workloads own that).  Emits rounds/s per
    algorithm plus each one's ratio to fedcm."""
    x, y, *_ = make_synthetic_classification(
        n_classes=10, dim=dims[0], n_train=6400, n_test=10
    )
    model = mlp_classifier(dims)
    loss_fn = classification_loss(model.apply)
    result = {"workload": {
        "num_clients": 64, "cohort_size": cohort, "local_steps": K,
        "batch_size": B, "rounds": rounds,
        "model": f"mlp {len(dims) - 1} layers ({2 * (len(dims) - 1)} leaves)",
        "path": "flat + fused kernels (use_fused_kernel=True)",
    }, "rounds_per_s": {}}
    for algo in list_algorithms():
        cfg = FedConfig(algo=algo, num_clients=64, cohort_size=cohort,
                        local_steps=K, participation="fixed",
                        use_fused_kernel=True)
        eng = FederatedEngine(cfg, loss_fn, batch_size=B)
        data = FederatedData(x, y, cfg.num_clients, seed=0)

        def fresh():
            return eng.init(model.init(jax.random.PRNGKey(0)),
                            jax.random.PRNGKey(1))

        st, _ = eng.run_rounds(fresh(), data, rounds)  # warm/compile
        _block(st)
        t0 = time.perf_counter()
        st, _ = eng.run_rounds(fresh(), data, rounds)
        _block(st)
        dt = time.perf_counter() - t0
        result["rounds_per_s"][algo] = round(rounds / dt, 2)
    base = result["rounds_per_s"].get("fedcm") or 1.0
    result["vs_fedcm"] = {
        a: round(r / base, 2) for a, r in result["rounds_per_s"].items()
    }
    if not quiet:
        print(f"== algo_sweep ({result['workload']['model']}, C={cohort}, "
              f"K={K}, kernel path) ==")
        for a, r in sorted(result["rounds_per_s"].items()):
            print(f"  {a:<12} {r:>8} rounds/s  ({result['vs_fedcm'][a]}x fedcm)")
    return result


def _measure_compression(rounds, quiet, kinds=("none", "int8", "bf16", "topk")):
    """rounds/s + wire accounting per uplink compression kind.

    fedcm on the paper_scaled shape, flat + fused kernel (the dequant-fold
    route), one timed fused scan per kind.  Three numbers per kind, all
    from the SAME accounting the engine bills at runtime
    (``repro.core.compress``): per-client uplink bytes/round (from the
    run's ``bytes_up`` metric), the f32-relative reduction, and the async
    ring's per-slot in-flight bytes for the wire planes at this cohort —
    the D×cohort ring carries the COMPRESSED representation, so in-flight
    memory shrinks by the same factor the wire does."""
    import numpy as np

    from repro.configs.base import CompressionConfig
    from repro.core.compress import uplink_bytes_per_client
    from repro.core.registry import get_algorithm

    wl = WORKLOADS["paper_scaled"]
    dims, cohort, K, B = wl["dims"], wl["cohort"], wl["K"], wl["B"]
    x, y, *_ = make_synthetic_classification(
        n_classes=10, dim=dims[0], n_train=6400, n_test=10
    )
    model = mlp_classifier(dims)
    loss_fn = classification_loss(model.apply)
    spec_wire = get_algorithm("fedcm").wire_uplink_planes
    result = {"workload": {
        "algo": "fedcm", "num_clients": 64, "cohort_size": cohort,
        "local_steps": K, "batch_size": B, "rounds": rounds,
        "model": f"mlp {len(dims) - 1} layers ({2 * (len(dims) - 1)} leaves)",
        "path": "flat + fused kernels (dequant fold for int8/bf16)",
    }, "kinds": {}}
    base_bytes = None
    for kind in kinds:
        comp = (None if kind == "none"
                else CompressionConfig(kind=kind, topk_frac=0.05))
        cfg = FedConfig(algo="fedcm", num_clients=64, cohort_size=cohort,
                        local_steps=K, participation="fixed",
                        use_fused_kernel=True, compression=comp)
        eng = FederatedEngine(cfg, loss_fn, batch_size=B)
        data = FederatedData(x, y, cfg.num_clients, seed=0)

        def fresh():
            return eng.init(model.init(jax.random.PRNGKey(0)),
                            jax.random.PRNGKey(1))

        st, ms = eng.run_rounds(fresh(), data, rounds)  # warm/compile
        _block(st)
        t0 = time.perf_counter()
        st, ms = eng.run_rounds(fresh(), data, rounds)
        _block(st)
        dt = time.perf_counter() - t0
        # bytes_up = n_active × per-client wire bytes; fixed participation
        # here, so n_active == cohort every round
        up = int(np.asarray(ms.bytes_up)[-1]) // cohort
        if base_bytes is None:
            base_bytes = up
        # ring slot = the wire planes of one in-flight cohort, as stored
        # (compressed on the kernel path) — size from the same pricing fn
        size = sum(int(l.size) for l in jax.tree_util.tree_leaves(
            jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
        ring = cohort * uplink_bytes_per_client(comp, spec_wire, size, size * 4)
        result["kinds"][kind] = {
            "rounds_per_s": round(rounds / dt, 2),
            "uplink_bytes_per_client": up,
            "reduction_x": round(base_bytes / up, 2),
            "ring_bytes_per_slot": ring,
        }
    f32_ring = result["kinds"][kinds[0]]["ring_bytes_per_slot"]
    for k in result["kinds"]:
        result["kinds"][k]["ring_reduction_x"] = round(
            f32_ring / result["kinds"][k]["ring_bytes_per_slot"], 2)
    if not quiet:
        print(f"== uplink_compression ({result['workload']['model']}, "
              f"C={cohort}, K={K}, kernel path) ==")
        for k, r in result["kinds"].items():
            print(f"  {k:<5} {r['rounds_per_s']:>8} rounds/s  "
                  f"{r['uplink_bytes_per_client']:>7} B/client "
                  f"({r['reduction_x']}x)  ring/slot "
                  f"{r['ring_bytes_per_slot']:>8} B ({r['ring_reduction_x']}x)")
    return result


def _measure_store_prefetch(rounds, alts, quiet, n_clients=256, cohort=16):
    """Host-store loop: synchronous vs double-buffered (store_prefetch).

    scaffold (client state makes the store gather/scatter real work) on the
    paper_scaled shape through ``run_rounds_store``; the prefetch loop
    overlaps the NEXT cohort's store gather + host batch build with the
    current round's device step.  The two loops are bitwise-identical by
    contract (tests assert it); this records what the overlap buys in
    rounds/s — interleaved min-of-N, plus the drift-robust median of
    pairwise per-alternation ratios."""
    wl = WORKLOADS["paper_scaled"]
    dims, K, B = wl["dims"], wl["K"], wl["B"]
    x, y, *_ = make_synthetic_classification(
        n_classes=10, dim=dims[0], n_train=6400, n_test=10
    )
    model = mlp_classifier(dims)
    loss_fn = classification_loss(model.apply)
    engines = {}
    for key, pf in (("sync", False), ("prefetch", True)):
        cfg = FedConfig(algo="scaffold", num_clients=n_clients,
                        cohort_size=cohort, local_steps=K,
                        participation="fixed", use_fused_kernel=True,
                        population_store="host", store_prefetch=pf)
        engines[key] = FederatedEngine(cfg, loss_fn, batch_size=B)
    data = FederatedData(x, y, n_clients, seed=0)

    def run(eng):
        st = eng.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1))
        st, _ = eng.run_rounds(st, data, rounds)
        _block(st)

    for e in engines.values():  # warm/compile
        run(e)
    times = {k: [] for k in engines}
    for _ in range(alts):
        for k, e in engines.items():
            t0 = time.perf_counter()
            run(e)
            times[k].append(time.perf_counter() - t0)
    best = {k: min(v) for k, v in times.items()}
    pairwise = sorted(s / p for s, p in zip(times["sync"], times["prefetch"]))
    result = {
        "workload": {
            "algo": "scaffold", "num_clients": n_clients,
            "cohort_size": cohort, "local_steps": K, "batch_size": B,
            "rounds": rounds, "population_store": "host",
            "timing": f"interleaved min/median-pairwise of {alts}",
        },
        "sync_s": round(best["sync"], 4),
        "prefetch_s": round(best["prefetch"], 4),
        "sync_rounds_per_s": round(rounds / best["sync"], 2),
        "prefetch_rounds_per_s": round(rounds / best["prefetch"], 2),
        "prefetch_vs_sync": round(best["sync"] / best["prefetch"], 2),
        "prefetch_vs_sync_median": round(pairwise[len(pairwise) // 2], 2),
    }
    if not quiet:
        print(f"== store_prefetch (scaffold host store, N={n_clients}, "
              f"C={cohort}) ==")
        print(f"  sync loop:     {best['sync']:.3f}s  "
              f"({result['sync_rounds_per_s']} rounds/s)")
        print(f"  prefetch loop: {best['prefetch']:.3f}s  "
              f"({result['prefetch_rounds_per_s']} rounds/s, "
              f"{result['prefetch_vs_sync']}x min / "
              f"{result['prefetch_vs_sync_median']}x median vs sync)")
    return result


def write_trajectory_summary(result: dict) -> dict:
    """Append this run's rounds/s-per-workload row to the top-level
    ``BENCH_fused_rounds.json`` trajectory (one entry per commit — an
    existing entry for the same rev is replaced, so re-runs update in
    place).  Folds in the cohort-parallel sweep's artifact when
    ``benchmarks/cohort_sharded.py`` has run in this checkout AT THIS
    REV — a stale (checked-in, earlier-commit) artifact is flagged, not
    attributed to the current rev."""
    from benchmarks.common import git_rev

    entry = {
        "rev": git_rev(),
        "rounds_per_s": {
            "sequential": result["sequential_rounds_per_s"],
            "update_bound_tree": result["update_bound"]["tree_fused_rounds_per_s"],
            "update_bound_flat": result["update_bound"]["flat_fused_rounds_per_s"],
            "paper_scaled_flat": result["paper_scaled"]["flat_fused_rounds_per_s"],
            "async_d2": result["async_pipeline"]["async_d2_rounds_per_s"],
            "algo_sweep": result["algo_sweep"]["rounds_per_s"],
            "store_prefetch": result["store_prefetch"]["prefetch_rounds_per_s"],
            "store_sync": result["store_prefetch"]["sync_rounds_per_s"],
        },
        # wire accounting per compression kind (bytes/client, f32-relative
        # reduction, async ring in-flight bytes/slot) + kernel-path rounds/s
        "uplink_compression": result["uplink_compression"]["kinds"],
    }
    if COHORT_ARTIFACT.exists():
        cs = json.loads(COHORT_ARTIFACT.read_text())
        if cs.get("rev") == entry["rev"]:
            entry["cohort_sharded"] = {
                "devices_visible": cs.get("devices_visible"),
                "cpu_count": cs.get("cpu_count"),
            }
            for wl in ("update_bound", "update_bound_c64", "cohort_scaled"):
                if wl in cs:
                    row = cs[wl]
                    entry["cohort_sharded"][wl] = {
                        k: v for k, v in row.items()
                        if k.endswith(("rounds_per_s", "speedup"))
                    }
        else:
            entry["cohort_sharded"] = {"stale_rev": cs.get("rev")}
    if PARTICIPATION_ARTIFACT.exists():
        pr = json.loads(PARTICIPATION_ARTIFACT.read_text())
        if isinstance(pr, dict) and pr.get("rev") == entry["rev"]:
            # per-(N, regime, algo) accuracy + rounds/s of the host-store
            # population engine — the scenario harness's headline numbers
            entry["participation"] = [
                {k: row[k] for k in ("num_clients", "availability", "algo",
                                     "acc_final", "rounds_per_s")}
                for row in pr.get("rows", [])
            ]
        else:
            entry["participation"] = {
                "stale_rev": pr.get("rev") if isinstance(pr, dict) else "pre-harness"
            }
    if FAULT_ARTIFACT.exists():
        ft = json.loads(FAULT_ARTIFACT.read_text())
        if isinstance(ft, dict) and ft.get("rev") == entry["rev"]:
            # convergence-vs-fault-rate: acc per (algo, drop rate) plus the
            # degradation counters — the fault harness's headline numbers
            entry["fault_tolerance"] = [
                {k: row[k] for k in ("algo", "drop_rate", "acc_final",
                                     "params_finite", "n_dropped",
                                     "n_quarantined", "quorum_skipped")}
                for row in ft.get("rows", [])
            ]
        else:
            entry["fault_tolerance"] = {
                "stale_rev": ft.get("rev") if isinstance(ft, dict) else "pre-harness"
            }
    if BITS_ARTIFACT.exists():
        cb = json.loads(BITS_ARTIFACT.read_text())
        if isinstance(cb, dict) and cb.get("rev") == entry["rev"]:
            # convergence-vs-bits: acc per (algo, kind) + wire accounting —
            # the compressed-uplink harness's headline numbers
            entry["convergence_bits"] = [
                {k: row[k] for k in ("algo", "kind", "acc_final",
                                     "acc_vs_f32", "uplink_bytes_per_client",
                                     "reduction_x")}
                for row in cb.get("rows", [])
            ]
        else:
            entry["convergence_bits"] = {
                "stale_rev": cb.get("rev") if isinstance(cb, dict) else "pre-harness"
            }
    if FLEET_ARTIFACT.exists():
        from repro.fleet.telemetry import events, replay, round_rows

        try:
            header, rows, _ = replay(FLEET_ARTIFACT)
        except ValueError:
            header, rows = {"meta": {}}, []
        if header.get("meta", {}).get("rev") == entry["rev"]:
            # the --serve run's per-round record: throughput series with
            # eval points, plus the serving thread's swap/health summary
            rnds = round_rows(rows)
            summaries = events(rows, "serve_summary")
            probes = events(rows, "health_probe")
            entry["fleet"] = {
                "rounds": len(rnds),
                "rounds_per_s": [r["rounds_per_s"] for r in rnds],
                "eval_acc": [
                    {"round": r["round"], "acc": r["eval_acc"]}
                    for r in rnds if r.get("eval_acc") is not None
                ],
                "serve": ({k: summaries[-1].get(k) for k in
                           ("steps", "swaps", "swaps_mid_session",
                            "served_version")} if summaries else None),
                "health_status": probes[-1].get("status") if probes else None,
            }
        else:
            entry["fleet"] = {"stale_rev": header.get("meta", {}).get("rev")}
    data = {"trajectory": []}
    if BENCH_SUMMARY.exists():
        try:
            data = json.loads(BENCH_SUMMARY.read_text())
        except json.JSONDecodeError:
            pass
    traj = [e for e in data.get("trajectory", []) if e.get("rev") != entry["rev"]]
    traj.append(entry)
    data = {"trajectory": traj, "latest": entry}
    BENCH_SUMMARY.write_text(json.dumps(data, indent=1))
    return entry


def main(rounds: int = 60, alts: int = 8, quiet: bool = False) -> dict:
    result = {
        name: _measure(name, rounds=rounds, alts=alts, quiet=quiet, **wl)
        for name, wl in WORKLOADS.items()
    }
    result["async_pipeline"] = _measure_async(rounds, alts, quiet)
    result["algo_sweep"] = _measure_algo_sweep(rounds, quiet)
    result["uplink_compression"] = _measure_compression(rounds, quiet)
    result["store_prefetch"] = _measure_store_prefetch(
        rounds, max(2, alts // 2), quiet
    )
    # legacy top-level keys mirror the headline workload
    head = result["update_bound"]
    for k in ("sequential_s", "flat_fused_s", "tree_fused_s", "speedup",
              "flat_vs_tree_speedup"):
        result[k] = head[k]
    result["fused_s"] = head["flat_fused_s"]
    result["sequential_rounds_per_s"] = head["sequential_rounds_per_s"]
    result["fused_rounds_per_s"] = head["flat_fused_rounds_per_s"]
    ARTIFACT.parent.mkdir(parents=True, exist_ok=True)
    ARTIFACT.write_text(json.dumps(result, indent=1))
    write_trajectory_summary(result)
    if not quiet:
        print(f"  (artifact: {ARTIFACT.name}; trajectory: {BENCH_SUMMARY.name})")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--alts", type=int, default=8,
                    help="interleaved timing repetitions per path")
    args = ap.parse_args()
    main(rounds=args.rounds, alts=args.alts)
