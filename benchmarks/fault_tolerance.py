"""Convergence-vs-fault-rate curves (PR-7 fault-tolerant execution).

Sweeps the per-client uplink drop rate {0.0, 0.2, 0.4} with a constant 1%
NaN payload-corruption rate (every nonzero-fault cell also exercises the
quarantine path) over fedcm / fedavg / scaffold — the paper's momentum
method against the stateless and stateful baselines — and records final
test accuracy, mean surviving cohort size, total dropped / quarantined
uplinks, and quorum-skipped rounds.  The question the curve answers:
how much accuracy does client-level momentum buy back as the uplink gets
lossier?

Faults ride the engine as pure ``FaultConfig`` data (seeded stream keyed
by absolute round x client id, so every cell is reproducible); drop-rate
0.0 runs with ``fault=None`` — the bitwise-preserved baseline engine.

The artifact is rev-stamped; ``benchmarks/fused_rounds.py`` folds the
rows into the top-level ``BENCH_fused_rounds.json`` trajectory summary
when the revs match.

    PYTHONPATH=src python -m benchmarks.fault_tolerance [--rounds 40]
"""
from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import git_rev, print_table, save_artifact
from repro.configs.base import FaultConfig, FedConfig
from repro.core import FederatedEngine, make_eval_fn
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

ALGOS = ["fedcm", "fedavg", "scaffold"]
DROP_RATES = [0.0, 0.2, 0.4]
CORRUPT_RATE = 0.01  # constant NaN-plane corruption alongside every sweep cell

DIM, N_CLASSES, HIDDEN = 32, 10, 64
N_CLIENTS, COHORT, LOCAL_STEPS, BATCH = 100, 10, 5, 20


def run_cell(algo: str, drop_rate: float, rounds: int, seed: int = 0) -> dict:
    fault = None
    if drop_rate > 0.0:
        fault = FaultConfig(drop_rate=drop_rate, corrupt_rate=CORRUPT_RATE,
                            corrupt_mode="nan", seed=seed)
    cfg = FedConfig(
        algo=algo, num_clients=N_CLIENTS, cohort_size=COHORT,
        local_steps=LOCAL_STEPS, alpha=0.1, eta_l=0.05, eta_g=1.0,
        participation="bernoulli", rounds=rounds, seed=seed,
        fault=fault, min_quorum=2,
    )
    x_tr, y_tr, x_te, y_te = make_synthetic_classification(
        n_classes=N_CLASSES, dim=DIM, n_train=20_000, n_test=2_000, seed=seed)
    data = FederatedData(x_tr, y_tr, N_CLIENTS, dirichlet_alpha=0.6, seed=seed)
    model = mlp_classifier((DIM, HIDDEN, HIDDEN, N_CLASSES))
    eng = FederatedEngine(cfg, classification_loss(model.apply),
                          batch_size=BATCH)
    state = eng.init(model.init(jax.random.PRNGKey(seed)),
                     jax.random.PRNGKey(seed + 1))
    state, ms = eng.run_rounds(state, data, rounds)
    evaluate = make_eval_fn(model.apply)
    acc = evaluate(state.params, jnp.asarray(x_te), jnp.asarray(y_te))
    finite = all(bool(jnp.all(jnp.isfinite(l)))
                 for l in jax.tree_util.tree_leaves(state.params))
    row = {
        "algo": algo,
        "drop_rate": drop_rate,
        "corrupt_rate": CORRUPT_RATE if fault is not None else 0.0,
        "acc_final": round(float(acc), 4),
        "params_finite": finite,
        "mean_active": round(float(np.mean(np.asarray(ms.n_active))), 2),
    }
    if fault is not None:
        row["n_dropped"] = int(np.sum(np.asarray(ms.n_dropped)))
        row["n_quarantined"] = int(np.sum(np.asarray(ms.n_quarantined)))
        row["quorum_skipped"] = int(np.sum(np.asarray(ms.quorum_skipped)))
    else:
        row["n_dropped"] = row["n_quarantined"] = row["quorum_skipped"] = 0
    return row


def main(rounds: int = 40, seed: int = 0) -> list:
    rows = []
    for drop in DROP_RATES:
        for algo in ALGOS:
            row = run_cell(algo, drop, rounds, seed=seed)
            rows.append(row)
            print(f"  drop={drop:<4} {algo:9s} acc={row['acc_final']:.4f} "
                  f"finite={row['params_finite']} "
                  f"active={row['mean_active']:5.2f} "
                  f"dropped={row['n_dropped']} quar={row['n_quarantined']} "
                  f"skipped={row['quorum_skipped']}")
    save_artifact("fault_tolerance", {"rev": git_rev(), "rows": rows})
    print_table("Convergence vs fault rate (1% NaN corruption alongside)",
                rows, ["algo", "drop_rate", "acc_final", "params_finite",
                       "mean_active", "n_dropped", "n_quarantined",
                       "quorum_skipped"])
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    main(a.rounds, a.seed)
