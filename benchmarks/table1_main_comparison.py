"""Tables 1 & 2 (scaled): all algorithms × settings I/II × IID/Dirichlet.

Paper claims under test (EXPERIMENTS.md §Repro maps each to a column):
  C1  FedCM converges fastest (acc_mid highest)
  C2  FedCM is robust to the participation drop I→II (smallest Δ)
  C3  FedCM's IID↔non-IID gap is small
  C4  FedCM's convergence is the most stable (lowest acc_std)
"""
from __future__ import annotations

import argparse

from benchmarks.common import (
    SETTING_I,
    SETTING_II,
    aggregate_seeds,
    print_table,
    run_one,
    save_artifact,
)

ALGOS = ["fedcm", "fedavg", "fedadam", "scaffold", "feddyn", "mimelite"]


def main(rounds: int = 150, seeds: int = 2, algos=None) -> list:
    algos = algos or ALGOS
    rows = []
    for setting in (SETTING_I, SETTING_II):
        for dirichlet in (float("inf"), 0.3):
            split = "IID" if dirichlet == float("inf") else f"Dir-{dirichlet}"
            for algo in algos:
                per_seed = [
                    run_one(algo, setting, dirichlet, rounds, seed=s)
                    for s in range(seeds)
                ]
                row = aggregate_seeds(per_seed)
                row["split"] = split
                rows.append(row)
                print(f"  {setting.name:24s} {split:8s} {algo:9s} "
                      f"mid={row['acc_mid']:.4f} final={row['acc_final']:.4f} "
                      f"±{row['acc_std']:.4f}")
    save_artifact("table1_main_comparison", rows)
    print_table(
        "Table 1/2 (scaled): test accuracy",
        rows, ["setting", "split", "algo", "acc_mid", "acc_final", "acc_std"],
    )
    # claim deltas
    def cell(setting, split, algo, key):
        for r in rows:
            if r["setting"] == setting.name and r["split"] == split and r["algo"] == algo:
                return r[key]
        return None

    print("\n### participation-drop I→II (final acc, Dir split) — paper claim C2")
    for algo in algos:
        a1 = cell(SETTING_I, "Dir-0.3", algo, "acc_final")
        a2 = cell(SETTING_II, "Dir-0.3", algo, "acc_final")
        if a1 and a2:
            print(f"  {algo:9s}  I={a1:.4f}  II={a2:.4f}  drop={a1 - a2:+.4f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--algos", nargs="*", default=None)
    a = ap.parse_args()
    main(a.rounds, a.seeds, a.algos)
