"""Render EXPERIMENTS.md result tables from artifacts.

    PYTHONPATH=src python -m benchmarks.report [--write]

Prints (or splices into EXPERIMENTS.md at the <!-- RESULTS:* --> markers)
markdown tables for: the dry-run pair matrix, the roofline table, and the
federated benchmark tables if their artifacts exist.
"""
from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

from benchmarks.roofline import load_rows, model_flops_per_device
from repro.configs.base import ARCH_IDS, SHAPES
from repro.launch.mesh import PEAK_FLOPS_BF16

HERE = Path(__file__).resolve().parent
DRYRUN = HERE / "artifacts" / "dryrun"
ART = HERE / "artifacts"
EXP = HERE.parent / "EXPERIMENTS.md"


def _fmt_bytes(b):
    if b is None:
        return "?"
    for u in ("B", "KiB", "MiB", "GiB"):
        if b < 1024:
            return f"{b:.1f}{u}"
        b /= 1024
    return f"{b:.2f}TiB"


def dryrun_table() -> str:
    lines = [
        "| arch | shape | mesh | kind | compile_s | temp bytes/chip | FLOPs/chip | coll bytes/chip | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    from repro.launch.dryrun import LONG_OK

    for arch in ARCH_IDS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                lines.append(
                    f"| {arch} | {shape} | — | — | — | — | — | — | SKIP (full attention; DESIGN §6) |"
                )
                continue
            for mesh in ("single_pod_16x16", "multi_pod_2x16x16"):
                p = DRYRUN / f"{arch}_{shape}_{mesh}.json"
                if not p.exists():
                    lines.append(f"| {arch} | {shape} | {mesh} | — | — | — | — | — | MISSING |")
                    continue
                d = json.loads(p.read_text())
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {d['kind']} | {d['compile_seconds']} | "
                    f"{_fmt_bytes(d['memory']['temp_bytes'])} | {d['hlo_flops_per_device']:.2e} | "
                    f"{d['collective_bytes_per_device']:.2e} | PASS |"
                )
    return "\n".join(lines)


def roofline_table() -> str:
    rows = load_rows("single_pod_16x16")
    lines = [
        "| arch | shape | compute_ms | memory_ms | collective_ms | bottleneck | MODEL_FLOPS/HLO | one-line fix |",
        "|---|---|---|---|---|---|---|---|",
    ]
    fixes = {
        ("memory", "train"): "bf16-ize / fuse the dominant elementwise chains; bigger microbatches amortize FSDP gathers",
        ("memory", "decode"): "KV-cache dtype + layout (ring buffer for windowed layers); fuse cache update",
        ("memory", "prefill"): "flash-attention kernel removes score materialization",
        ("collective", "train"): "sequence-sharded residuals: all-reduce → reduce-scatter+all-gather (½ bytes)",
        ("collective", "decode"): "replicate small tensors; batch the per-layer psums",
        ("collective", "prefill"): "overlap TP collectives with the next layer's matmul",
        ("compute", "train"): "already MXU-bound — raise per-chip batch",
        ("compute", "decode"): "decode is latency-bound; batch more sequences",
        ("compute", "prefill"): "already MXU-bound",
    }
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        fix = fixes.get((r["bottleneck"], r["kind"]), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_ms']} | {r['memory_ms']} | "
            f"{r['collective_ms']} | **{r['bottleneck']}** | {r['model_flops_ratio']} | {fix} |"
        )
    return "\n".join(lines)


def fed_tables() -> dict:
    out = {}
    t1 = ART / "table1_main_comparison.json"
    if t1.exists():
        rows = json.loads(t1.read_text())
        lines = ["| setting | split | algo | acc@40% | acc final | std |", "|---|---|---|---|---|---|"]
        for r in rows:
            lines.append(
                f"| {r['setting']} | {r['split']} | {r['algo']} | {r['acc_mid']:.4f} | "
                f"{r['acc_final']:.4f} | {r['acc_std']:.4f} |"
            )
        out["TABLE1"] = "\n".join(lines)
    t3 = ART / "table3_alpha_sensitivity.json"
    if t3.exists():
        rows = json.loads(t3.read_text())
        lines = ["| α | acc@40% | acc final | std |", "|---|---|---|---|"]
        for r in rows:
            lines.append(f"| {r['alpha']} | {r['acc_mid']:.4f} | {r['acc_final']:.4f} | {r['acc_std']:.4f} |")
        out["TABLE3"] = "\n".join(lines)
    pr = ART / "participation_robustness.json"
    if pr.exists():
        rows = json.loads(pr.read_text())
        lines = ["| participation | algo | acc final | std |", "|---|---|---|---|"]
        for r in rows:
            lines.append(f"| {r['participation']} | {r['algo']} | {r['acc_final']:.4f} | {r['acc_std']:.4f} |")
        out["TABLE1"] = out.get("TABLE1", "") + "\n\nParticipation sweep (500 clients, Dir-0.3):\n\n" + "\n".join(lines)
    return out


def splice(marker: str, content: str, text: str) -> str:
    tag = f"<!-- RESULTS:{marker} -->"
    if tag not in text:
        return text
    return text.replace(tag, tag + "\n\n" + content + "\n")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true", help="splice into EXPERIMENTS.md")
    args = ap.parse_args()
    blocks = {"DRYRUN": dryrun_table(), "ROOFLINE": roofline_table()}
    blocks.update(fed_tables())
    if args.write:
        text = EXP.read_text()
        for k, v in blocks.items():
            text = splice(k, v, text)
        EXP.write_text(text)
        print(f"spliced {sorted(blocks)} into {EXP}")
    else:
        for k, v in blocks.items():
            print(f"\n===== {k} =====\n{v}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
