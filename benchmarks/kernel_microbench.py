"""Kernel micro-benchmarks: bytes/FLOPs accounting + CPU sanity timings.

On this container the Pallas kernels execute in interpret mode, so
wall-clock numbers are NOT TPU performance — the value here is (a) the
analytic bytes/FLOPs table (what the fusion saves on the roofline's memory
term) and (b) a correctness-at-size smoke.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.fed_direction.kernel import fed_direction_flat
from repro.kernels.fedcm_update.ref import fedcm_step_ref


def fedcm_update_accounting(n_params: int) -> dict:
    """HBM traffic for one local step over n_params (f32)."""
    b = 4 * n_params
    unfused = {  # v = αg + (1−α)Δ ; x = x − ηv  as two ops
        "reads": 2 * b + 2 * b,  # (g, Δ) then (x, v)
        "writes": b + b,  # v then x
    }
    fused = {"reads": 3 * b, "writes": b}
    return {
        "n_params": n_params,
        "unfused_bytes": unfused["reads"] + unfused["writes"],
        "fused_bytes": fused["reads"] + fused["writes"],
        "saving": 1 - (fused["reads"] + fused["writes"]) / (unfused["reads"] + unfused["writes"]),
    }


def main() -> int:
    print("### fused local-step accounting (fed_direction blend, per local step)")
    for n in [1_000_000, 11_000_000, 390_000_000]:  # ~ResNet18 / ~llama3.2 emb / llama4
        acc = fedcm_update_accounting(n)
        print(f"  n={n:>11,d}  unfused={acc['unfused_bytes']/1e9:7.2f} GB  "
              f"fused={acc['fused_bytes']/1e9:7.2f} GB  saving={acc['saving']:.0%}")

    print("\n### correctness at size (interpret mode)")
    # the FedCM blend now launches through the generalized fed_direction
    # kernel (the dedicated fedcm_update kernel is retired to ref-only);
    # coefficients (η, α, 0, 1−α) select the blend form
    rng = np.random.default_rng(0)
    n = 4_000_000
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    d = jnp.asarray(rng.normal(size=n), jnp.float32)
    coefs = jnp.asarray([0.05, 0.1, 0.0, 0.9], jnp.float32)
    t0 = time.time()
    out = jax.block_until_ready(fed_direction_flat(x, g, (d,), coefs))
    t_k = time.time() - t0
    ref = fedcm_step_ref(x, g, d, 0.1, 0.05)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"  n={n:,}: max|err|={err:.2e}  (interpret-mode wall {t_k*1e3:.0f} ms — not TPU perf)")
    assert err < 1e-6
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
