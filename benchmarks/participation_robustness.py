"""Participation-rate sweep (the paper's §6.2 robustness claim, sharpened).

Sweeps the cohort size at fixed N=500 and measures how each algorithm's
final accuracy and stability degrade as participation → 0.6%.  FedCM's
momentum carries gradient information of past cohorts, so its degradation
curve should be the flattest; SCAFFOLD's stale control variates should
degrade it fastest (what the paper observed going 10% → 2%).
"""
from __future__ import annotations

import argparse

from benchmarks.common import Setting, print_table, run_one, save_artifact

COHORTS = [25, 10, 3]
ALGOS = ["fedcm", "fedavg", "scaffold"]


def main(rounds: int = 150, seeds: int = 2) -> list:
    import numpy as np

    rows = []
    for cohort in COHORTS:
        setting = Setting(f"500 clients, {cohort/5:.1f}%", 500, cohort, 50)
        for algo in ALGOS:
            per_seed = [run_one(algo, setting, 0.3, rounds, seed=s) for s in range(seeds)]
            row = {
                "cohort": cohort,
                "participation": f"{cohort/5:.1f}%",
                "algo": algo,
                "acc_final": round(float(np.mean([r["acc_final"] for r in per_seed])), 4),
                "acc_std": round(float(np.mean([r["acc_std"] for r in per_seed])), 4),
            }
            rows.append(row)
            print(f"  cohort={cohort:<3} {algo:9s} final={row['acc_final']:.4f} ±{row['acc_std']:.4f}")
    save_artifact("participation_robustness", rows)
    print_table("Participation sweep (500 clients, Dir-0.3)", rows,
                ["participation", "algo", "acc_final", "acc_std"])
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--seeds", type=int, default=2)
    a = ap.parse_args()
    main(a.rounds, a.seeds)
