"""Participation-robustness scenario harness (paper §6.2 at fleet scale).

The original sweep shrank the cohort at N=500 resident clients.  This
harness instead holds participation fixed and scales the POPULATION —
N = 1e3 / 1e5 (and 1e6 with ``--full``) — under realistic availability
regimes, exercising the out-of-core population engine end to end:
``population_store="host"`` (sparse host store of client state, gathered
``(C, P)`` per cohort) + ``StreamingClientData`` (shards regenerate on
demand; nothing O(N) ever lands on device).

Regimes (≥3, per the availability processes in ``repro.data.population``):

  uniform   — legacy bernoulli participation (bitwise-preserved sampler)
  zipf      — traffic skew w_i ∝ (i+1)^-1.1 (head clients dominate)
  diurnal   — time-of-day sinusoid, amplitude 0.8, phase spread over clients
  dropout   — uniform draw, then 30% straggler dropout from the mask

Per row: final test accuracy, steady-state rounds/s (one warm-up round
excluded — it carries the jit compile), mean active clients, rounds that
hit the bernoulli capacity clip (surfaced via ``RoundMetrics.n_clipped``),
and how many distinct clients the host store touched.

The artifact is rev-stamped; ``benchmarks/fused_rounds.py`` folds the rows
into the top-level ``BENCH_fused_rounds.json`` trajectory summary when the
revs match.

    PYTHONPATH=src python -m benchmarks.participation_robustness \
        [--rounds 30] [--full]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import git_rev, print_table, save_artifact
from repro.configs.base import FedConfig
from repro.core import FederatedEngine, make_eval_fn
from repro.data import StreamingClientData
from repro.models.small import classification_loss, mlp_classifier

N_SWEEP = [1_000, 100_000]
N_FULL = 1_000_000
ALGOS = ["fedcm", "scaffold"]  # stateless + stateful (store-backed c_i)

REGIMES = [
    {"name": "uniform", "availability": "uniform", "dropout_rate": 0.0},
    {"name": "zipf-1.1", "availability": "zipf", "dropout_rate": 0.0,
     "zipf_exponent": 1.1},
    {"name": "diurnal-0.8", "availability": "diurnal", "dropout_rate": 0.0},
    {"name": "dropout-0.3", "availability": "uniform", "dropout_rate": 0.3},
]

DIM, N_CLASSES, HIDDEN = 32, 10, 64
COHORT, LOCAL_STEPS, BATCH = 20, 5, 20


def run_scenario(algo: str, num_clients: int, regime: dict, rounds: int,
                 seed: int = 0) -> dict:
    cfg = FedConfig(
        algo=algo, num_clients=num_clients, cohort_size=COHORT,
        local_steps=LOCAL_STEPS, alpha=0.1, eta_l=0.05, eta_g=1.0,
        participation="bernoulli", rounds=rounds, seed=seed,
        population_store="host",
        availability=regime["availability"],
        dropout_rate=regime["dropout_rate"],
        zipf_exponent=regime.get("zipf_exponent", 1.1),
    )
    task = StreamingClientData(num_clients, dim=DIM, n_classes=N_CLASSES,
                               seed=seed)
    model = mlp_classifier((DIM, HIDDEN, HIDDEN, N_CLASSES))
    eng = FederatedEngine(cfg, classification_loss(model.apply),
                          batch_size=BATCH)
    state = eng.init(model.init(jax.random.PRNGKey(seed)),
                     jax.random.PRNGKey(seed + 1))
    # warm-up round carries the per-round jit compiles — excluded from rate
    state, _ = eng.run_rounds(state, task, 1)
    t0 = time.time()
    state, ms = eng.run_rounds(state, task, rounds)
    dt = time.time() - t0
    evaluate = make_eval_fn(model.apply)
    x_te, y_te = task.test_set(2_000)
    acc = evaluate(state.params, jnp.asarray(x_te), jnp.asarray(y_te))
    n_clipped = np.asarray(ms.n_clipped)
    return {
        "num_clients": num_clients,
        "availability": regime["name"],
        "algo": algo,
        "acc_final": round(float(acc), 4),
        "rounds_per_s": round(rounds / dt, 2),
        "mean_active": round(float(np.mean(np.asarray(ms.n_active))), 2),
        "clip_rounds": int(np.sum(n_clipped > 0)),
        "touched_clients": (eng.population.touched
                            if eng.population is not None else 0),
    }


def main(rounds: int = 30, full: bool = False, seed: int = 0) -> list:
    sweep = N_SWEEP + ([N_FULL] if full else [])
    rows = []
    for n in sweep:
        for regime in REGIMES:
            for algo in ALGOS:
                row = run_scenario(algo, n, regime, rounds, seed=seed)
                rows.append(row)
                print(f"  N={n:<8} {regime['name']:<12} {algo:9s} "
                      f"acc={row['acc_final']:.4f} "
                      f"{row['rounds_per_s']:6.2f} rounds/s "
                      f"active={row['mean_active']:5.1f} "
                      f"clips={row['clip_rounds']} "
                      f"touched={row['touched_clients']}")
    save_artifact("participation_robustness", {"rev": git_rev(), "rows": rows})
    print_table("Participation scenarios (host store, streaming shards)",
                rows, ["num_clients", "availability", "algo", "acc_final",
                       "rounds_per_s", "mean_active", "clip_rounds",
                       "touched_clients"])
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--full", action="store_true",
                    help="add the N=1e6 tier to the sweep")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    main(a.rounds, a.full, a.seed)
