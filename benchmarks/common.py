"""Shared harness for the paper-table benchmarks (scaled reproduction).

Protocol (EXPERIMENTS.md §Repro): the CIFAR/ResNet-18×4000-round experiments
of the paper are reproduced at container scale on a synthetic Gaussian
mixture with controlled Bayes error (separation 0.9 / noise 2.0 ≈ 60–80%
achievable accuracy) and an MLP with GroupNorm-free layers.  Scaled
settings mirror §6.1:

  Setting I  : 100 clients, 10% participation (bernoulli), 100 pts/client
  Setting II : 500 clients,  2% participation (bernoulli),  50 pts/client

Metrics per run:
  acc_mid      — accuracy at the 40%-budget round (convergence speed)
  acc_final    — mean accuracy over the last 20% of rounds (quality)
  acc_std      — std over those evals (stability / oscillation — Fig. 2-3's
                 visual claim, quantified)

Per-algorithm server LRs follow appendix C.2 (η_g=1 averaging for all but
FedAdam, which uses a small absolute server LR).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import FederatedEngine, make_eval_fn
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"

# paper appendix C.2: per-algorithm hyperparameters
ETA_G = {"fedadam": 0.03}
ALPHA = {"fedadam": 0.1}
FEDDYN_ALPHA = 0.01

N_CLASSES = 20
DIM = 32
SEP, NOISE = 0.9, 2.0


@dataclass
class Setting:
    name: str
    num_clients: int
    cohort_size: int
    pts_per_client: int


# the paper splits ONE fixed corpus (CIFAR 50k) over 100 or 500 clients —
# total data must match across settings or the comparison confounds
# participation with dataset size (25 000 points here → 250/50 per client)
SETTING_I = Setting("I (100 clients, 10%)", 100, 10, 250)
SETTING_II = Setting("II (500 clients, 2%)", 500, 10, 50)


def make_task(setting: Setting, seed: int = 0):
    n_train = setting.num_clients * setting.pts_per_client
    x_tr, y_tr, x_te, y_te = make_synthetic_classification(
        n_classes=N_CLASSES, dim=DIM, n_train=n_train, n_test=4000,
        noise=NOISE, separation=SEP, seed=seed,
    )
    model = mlp_classifier((DIM, 128, 64, N_CLASSES))
    return x_tr, y_tr, x_te, y_te, model


def run_one(
    algo: str,
    setting: Setting,
    dirichlet: float,
    rounds: int,
    seed: int = 0,
    alpha: Optional[float] = None,
    local_steps: int = 20,
    eta_l: float = 0.05,
    track_curve: bool = False,
) -> Dict:
    x_tr, y_tr, x_te, y_te, model = make_task(setting, seed=seed)
    loss_fn = classification_loss(model.apply)
    a = alpha if alpha is not None else ALPHA.get(algo, 0.05)
    cfg = FedConfig(
        algo=algo, num_clients=setting.num_clients, cohort_size=setting.cohort_size,
        local_steps=local_steps, alpha=a, eta_l=eta_l,
        eta_g=ETA_G.get(algo, 1.0), participation="bernoulli",
        weight_decay=1e-3, eta_l_decay=0.998, feddyn_alpha=FEDDYN_ALPHA,
        rounds=rounds, seed=seed,
    )
    data = FederatedData(x_tr, y_tr, cfg.num_clients, dirichlet_alpha=dirichlet, seed=seed)
    eng = FederatedEngine(cfg, loss_fn, batch_size=20)
    state = eng.init(model.init(jax.random.PRNGKey(seed)), jax.random.PRNGKey(seed + 1))
    evaluate = make_eval_fn(model.apply)
    x_te_j, y_te_j = jnp.asarray(x_te), jnp.asarray(y_te)

    mid_round = int(rounds * 0.4)
    tail_start = int(rounds * 0.8)
    acc_mid, tail, curve = None, [], []
    t0 = time.time()
    # fused execution: rounds between eval checkpoints run as scanned
    # programs (engine.run_rounds) — same rng threading as run_round × n,
    # so the trajectory (and all table numbers) is unchanged, only faster.
    # Chunks walk in a fixed stride: each DISTINCT chunk length is a fresh
    # compile of the whole scanned round program (n_rounds is static), so
    # stride-sized segments + small remainders keep that to a few sizes
    # regardless of `rounds` instead of one compile per checkpoint gap.
    eval_rounds = {mid_round, rounds - 1}
    eval_rounds |= {r for r in range(tail_start, rounds) if r % 5 == 0}
    if track_curve:
        eval_rounds |= set(range(0, rounds, 5))
    stride = 5
    last = -1
    ms = None
    for r in sorted(eval_rounds):
        while last < r:
            step = min(stride, r - last)
            state, ms = eng.run_rounds(state, data, step)
            last += step
        m = jax.tree_util.tree_map(lambda a: a[-1], ms)
        acc = evaluate(state.params, x_te_j, y_te_j)
        if r == mid_round:
            acc_mid = acc
        if r >= tail_start and (r % 5 == 0 or r == rounds - 1):
            tail.append(acc)
        if track_curve and r % 5 == 0:
            curve.append((r, acc))
    out = {
        "algo": algo, "setting": setting.name, "dirichlet": dirichlet,
        "alpha": a, "rounds": rounds, "seed": seed,
        "acc_mid": round(float(acc_mid), 4),
        "acc_final": round(float(np.mean(tail)), 4),
        "acc_std": round(float(np.std(tail)), 4),
        "train_loss": round(float(m.loss), 4),
        "wall_s": round(time.time() - t0, 1),
    }
    if track_curve:
        out["curve"] = curve
    return out


def aggregate_seeds(rows: List[Dict]) -> Dict:
    """Mean over seeds of one (algo, setting, split) cell."""
    out = dict(rows[0])
    for k in ("acc_mid", "acc_final", "acc_std"):
        out[k] = round(float(np.mean([r[k] for r in rows])), 4)
    out["n_seeds"] = len(rows)
    return out


def save_artifact(name: str, obj) -> Path:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    p = ARTIFACTS / f"{name}.json"
    p.write_text(json.dumps(obj, indent=1))
    return p


def print_table(title: str, rows: List[Dict], cols: List[str]):
    print(f"\n### {title}")
    widths = {c: max(len(c), max((len(str(r.get(c, ''))) for r in rows), default=0)) for c in cols}
    print("  " + "  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  " + "  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))


def git_rev() -> str:
    """Short rev of the working checkout — perf artifacts stamp themselves
    with it so trajectory rows never attribute one commit's numbers to
    another (the cohort_sharded sweep runs in a separate process/CI job
    from the fused_rounds summary that folds it in)."""
    import subprocess

    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, cwd=Path(__file__).parent,
            timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"
