"""Convergence-vs-bits curves: accuracy as the uplink wire narrows.

Sweeps the uplink compression kind {f32, bf16, int8, topk} over fedcm and
scaffold — the paper's momentum method and the stateful baseline whose
``state_delta`` plane stresses the multi-plane wire — on the heterogeneous
toy split (dirichlet α=0.6) and records final test accuracy plus the
TOTAL uplink bytes the run actually billed (summed from the engine's
per-round ``bytes_up`` accounting, which the wire encoders reprice).  The
question the curve answers: how many bits does client-level momentum need
on the wire — int8 (≈4×) should sit within 1% of f32, and top-k with
error feedback documents how far a 10× squeeze drifts.

Compression rides the engine as pure ``CompressionConfig`` data (seeded
stochastic rounding keyed by absolute round × plane, so every cell is
reproducible); the f32 cell runs with ``compression=None`` — the
bitwise-preserved baseline engine.

The artifact is rev-stamped; ``benchmarks/fused_rounds.py`` folds the
rows into the top-level ``BENCH_fused_rounds.json`` trajectory summary
when the revs match.

    PYTHONPATH=src python -m benchmarks.convergence_bits [--rounds 40]
"""
from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import git_rev, print_table, save_artifact
from repro.configs.base import CompressionConfig, FedConfig
from repro.core import FederatedEngine, make_eval_fn
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

ALGOS = ["fedcm", "scaffold"]
KINDS = [None, "bf16", "int8", "topk"]
TOPK_FRAC = 0.05

DIM, N_CLASSES, HIDDEN = 32, 10, 64
N_CLIENTS, COHORT, LOCAL_STEPS, BATCH = 100, 10, 5, 20


def run_cell(algo: str, kind, rounds: int, seed: int = 0) -> dict:
    comp = None
    if kind is not None:
        comp = CompressionConfig(kind=kind, topk_frac=TOPK_FRAC, seed=seed)
    cfg = FedConfig(
        algo=algo, num_clients=N_CLIENTS, cohort_size=COHORT,
        local_steps=LOCAL_STEPS, alpha=0.1, eta_l=0.05, eta_g=1.0,
        participation="bernoulli", rounds=rounds, seed=seed,
        use_fused_kernel=True, compression=comp,
    )
    x_tr, y_tr, x_te, y_te = make_synthetic_classification(
        n_classes=N_CLASSES, dim=DIM, n_train=20_000, n_test=2_000, seed=seed)
    data = FederatedData(x_tr, y_tr, N_CLIENTS, dirichlet_alpha=0.6, seed=seed)
    model = mlp_classifier((DIM, HIDDEN, HIDDEN, N_CLASSES))
    eng = FederatedEngine(cfg, classification_loss(model.apply),
                          batch_size=BATCH)
    state = eng.init(model.init(jax.random.PRNGKey(seed)),
                     jax.random.PRNGKey(seed + 1))
    state, ms = eng.run_rounds(state, data, rounds)
    evaluate = make_eval_fn(model.apply)
    acc = evaluate(state.params, jnp.asarray(x_te), jnp.asarray(y_te))
    # RoundMetrics.bytes_up = n_active × per-client wire bytes (the round's
    # cohort-total uplink); recover the per-client price from the last round
    bytes_up = np.asarray(ms.bytes_up, dtype=np.float64)
    n_active = np.asarray(ms.n_active, dtype=np.float64)
    per_client = bytes_up[-1] / max(n_active[-1], 1.0)
    return {
        "algo": algo,
        "kind": kind or "f32",
        "topk_frac": TOPK_FRAC if kind == "topk" else None,
        "acc_final": round(float(acc), 4),
        "uplink_bytes_per_client": int(per_client),
        "total_uplink_mb": round(float(bytes_up.sum()) / 2**20, 3),
        "params_finite": all(bool(jnp.all(jnp.isfinite(l)))
                             for l in jax.tree_util.tree_leaves(state.params)),
    }


def main(rounds: int = 40, seed: int = 0) -> list:
    rows = []
    for algo in ALGOS:
        base = None
        for kind in KINDS:
            row = run_cell(algo, kind, rounds, seed=seed)
            if kind is None:
                base = row
            row["reduction_x"] = round(
                base["uplink_bytes_per_client"]
                / max(row["uplink_bytes_per_client"], 1), 2)
            row["acc_vs_f32"] = round(row["acc_final"] - base["acc_final"], 4)
            rows.append(row)
            print(f"  {algo:9s} {row['kind']:<5} acc={row['acc_final']:.4f} "
                  f"(Δf32={row['acc_vs_f32']:+.4f}) "
                  f"{row['uplink_bytes_per_client']} B/client "
                  f"({row['reduction_x']}x) "
                  f"total={row['total_uplink_mb']} MiB")
    save_artifact("convergence_bits", {"rev": git_rev(), "rows": rows})
    print_table("Convergence vs uplink bits (dirichlet α=0.6 toy)",
                rows, ["algo", "kind", "acc_final", "acc_vs_f32",
                       "uplink_bytes_per_client", "reduction_x",
                       "total_uplink_mb", "params_finite"])
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    main(a.rounds, a.seed)
