"""Table 3 + Figure 1 (scaled): sensitivity of FedCM to α.

Paper claims: every α converges; too-small α oscillates/slows; α<1 beats
α=1 (=FedAvg); the sweet spot is α ≈ 0.05–0.1.  The convergence curves
(Figure 1) are saved in the artifact for plotting.
"""
from __future__ import annotations

import argparse

from benchmarks.common import SETTING_I, print_table, run_one, save_artifact

ALPHAS = [0.01, 0.03, 0.05, 0.1, 0.3, 1.0]  # table 3's grid


def main(rounds: int = 150, seeds: int = 2) -> list:
    rows = []
    for alpha in ALPHAS:
        per_seed = [
            run_one("fedcm", SETTING_I, 0.3, rounds, seed=s, alpha=alpha,
                    track_curve=(s == 0))
            for s in range(seeds)
        ]
        import numpy as np

        row = {
            "alpha": alpha,
            "acc_mid": round(float(np.mean([r["acc_mid"] for r in per_seed])), 4),
            "acc_final": round(float(np.mean([r["acc_final"] for r in per_seed])), 4),
            "acc_std": round(float(np.mean([r["acc_std"] for r in per_seed])), 4),
            "curve": per_seed[0].get("curve"),
        }
        rows.append(row)
        print(f"  alpha={alpha:<5} mid={row['acc_mid']:.4f} "
              f"final={row['acc_final']:.4f} ±{row['acc_std']:.4f}")
    save_artifact("table3_alpha_sensitivity", rows)
    print_table("Table 3 (scaled): FedCM α sensitivity", rows,
                ["alpha", "acc_mid", "acc_final", "acc_std"])
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--seeds", type=int, default=2)
    a = ap.parse_args()
    main(a.rounds, a.seeds)
