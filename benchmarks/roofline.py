"""Roofline table builder — reads the dry-run artifacts (deliverable (g)).

For every (arch × shape × mesh) JSON under benchmarks/artifacts/dryrun/:
  compute_s    = HLO_FLOPs / peak_FLOP/s          (per chip)
  memory_s     = HLO_bytes / HBM_bw               (per chip)
  collective_s = collective_bytes / ICI link bw   (per chip)
plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per chip, and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy).

Decode steps process D=B tokens (one per sequence); train/prefill process
D=B·S tokens.  Backward+forward ⇒ the 6·N·D estimate for training; forward
only ⇒ 2·N·D for prefill/decode.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs.base import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ARTIFACT_DIR = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        per_param = 6  # fwd 2 + bwd 4
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        per_param = 2
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        per_param = 2
    return per_param * n_active * tokens / chips


def load_rows(mesh_filter: Optional[str] = None) -> List[Dict]:
    rows = []
    for p in sorted(ARTIFACT_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        if any(f"{k}-" in p.stem for k in (
                "microbatches", "remat", "seq_shard", "serve_params",
                "moment_dtype", "grad_accum", "use_kernels")):
            continue  # hillclimb variants: §Perf reads them explicitly
        if mesh_filter and d["mesh"] != mesh_filter:
            continue
        terms = d["roofline"]
        mf = model_flops_per_device(d["arch"], d["shape"], d["chips"])
        useful = mf / d["hlo_flops_per_device"] if d["hlo_flops_per_device"] else 0.0
        dominant = max(
            ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
        )
        bound = terms[dominant]
        total = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "kind": d["kind"],
            "compute_ms": round(terms["compute_s"] * 1e3, 2),
            "memory_ms": round(terms["memory_s"] * 1e3, 2),
            "collective_ms": round(terms["collective_s"] * 1e3, 2),
            "bottleneck": dominant.replace("_s", ""),
            "model_flops_ratio": round(useful, 3),
            "bound_ms": round(total * 1e3, 2),
        })
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default="single_pod_16x16")
    args = ap.parse_args()
    rows = load_rows(args.mesh)
    if not rows:
        print("no dry-run artifacts found — run `python -m repro.launch.dryrun --all` first")
        return 1
    cols = ["arch", "shape", "kind", "compute_ms", "memory_ms", "collective_ms",
            "bottleneck", "model_flops_ratio"]
    w = {c: max(len(c), max(len(str(r[c])) for r in rows)) for c in cols}
    print("  ".join(c.ljust(w[c]) for c in cols))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print("  ".join(str(r[c]).ljust(w[c]) for c in cols))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
