"""Benchmark aggregator: `PYTHONPATH=src python -m benchmarks.run [--quick]`.

Runs one benchmark per paper table/figure plus the kernel accounting and —
if dry-run artifacts exist — the roofline table.  ``--quick`` trims rounds
and seeds for CI-speed runs; the full protocol (150 rounds × 2 seeds) is
what EXPERIMENTS.md records.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="short rounds/seeds")
    ap.add_argument("--skip-fed", action="store_true")
    args = ap.parse_args()

    rounds = 60 if args.quick else 150
    seeds = 1 if args.quick else 2
    t0 = time.time()

    print("=" * 72)
    print("BENCHMARK 1/6 — Table 1/2 (scaled): main algorithm comparison")
    print("=" * 72)
    if not args.skip_fed:
        from benchmarks.table1_main_comparison import main as t1

        t1(rounds=rounds, seeds=seeds)

    print("\n" + "=" * 72)
    print("BENCHMARK 2/6 — Table 3 + Fig 1 (scaled): FedCM alpha sensitivity")
    print("=" * 72)
    if not args.skip_fed:
        from benchmarks.table3_alpha_sensitivity import main as t3

        t3(rounds=rounds, seeds=seeds)

    print("\n" + "=" * 72)
    print("BENCHMARK 3/6 — participation robustness sweep")
    print("=" * 72)
    if not args.skip_fed:
        from benchmarks.participation_robustness import main as pr

        pr(rounds=rounds, seeds=seeds)

    print("\n" + "=" * 72)
    print("BENCHMARK 3b — convergence vs uplink bits (compressed wire)")
    print("=" * 72)
    if not args.skip_fed:
        from benchmarks.convergence_bits import main as cb

        cb(rounds=20 if args.quick else 40)

    print("\n" + "=" * 72)
    print("BENCHMARK 4/6 — kernel accounting + correctness at size")
    print("=" * 72)
    from benchmarks.kernel_microbench import main as km

    km()

    print("\n" + "=" * 72)
    print("BENCHMARK 5/6 — fused run_rounds scan vs per-round dispatch")
    print("=" * 72)
    if not args.skip_fed:
        from benchmarks.fused_rounds import main as fr

        # quick: fewer rounds AND fewer interleaved timing repetitions —
        # fused_rounds now measures two workloads (tree vs flat per each)
        fr(rounds=20 if args.quick else 60, alts=2 if args.quick else 8)

    print("\n" + "=" * 72)
    print("BENCHMARK 5b — cohort-parallel sweep (separate multi-device process)")
    print("=" * 72)
    if not args.skip_fed:
        # cohort_sharded must own its process: XLA_FLAGS (8 emulated
        # devices) has to be set before jax initializes, and this session's
        # jax is already live.  Its artifact feeds the next fused_rounds
        # trajectory row.
        import subprocess

        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.cohort_sharded",
             "--rounds", "6" if args.quick else "20", "--alts", "2"],
        )
        if r.returncode != 0:
            print("(cohort_sharded sweep failed — see output above)")

    print("\n" + "=" * 72)
    print("BENCHMARK 6/6 — roofline table (from dry-run artifacts)")
    print("=" * 72)
    from benchmarks.roofline import load_rows

    rows = load_rows("single_pod_16x16")
    if rows:
        cols = ["arch", "shape", "kind", "compute_ms", "memory_ms",
                "collective_ms", "bottleneck", "model_flops_ratio"]
        w = {c: max(len(c), max(len(str(r[c])) for r in rows)) for c in cols}
        print("  ".join(c.ljust(w[c]) for c in cols))
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            print("  ".join(str(r[c]).ljust(w[c]) for c in cols))
    else:
        print("(no dry-run artifacts yet — run `python -m repro.launch.dryrun --all`)")

    print(f"\nall benchmarks done in {time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
